"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import SphereDomain, fft_conv, local_dft
from repro.data.pipeline import DataConfig, Pipeline

SET = {"max_examples": 20, "deadline": None}


def _cx(seed, shape):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape)
            + 1j * rng.standard_normal(shape)).astype(np.complex64)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16, 24]),
       st.sampled_from([1, 3, 8]))
@settings(**SET)
def test_dft_linearity(seed, n, b):
    x = _cx(seed, (b, n))
    y = _cx(seed + 1, (b, n))
    a = 0.7 - 0.3j
    lhs = local_dft(jnp.asarray(a * x + y), -1)
    rhs = a * local_dft(jnp.asarray(x), -1) + local_dft(jnp.asarray(y), -1)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-3 * n)


@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 32]))
@settings(**SET)
def test_parseval_energy(seed, n):
    x = _cx(seed, (2, n))
    X = np.asarray(local_dft(jnp.asarray(x), -1))
    e_t = (np.abs(x) ** 2).sum(axis=-1)
    e_f = (np.abs(X) ** 2).sum(axis=-1) / n
    np.testing.assert_allclose(e_t, e_f, rtol=1e-3)


@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16]))
@settings(**SET)
def test_inverse_roundtrip(seed, n):
    x = _cx(seed, (2, n))
    y = local_dft(local_dft(jnp.asarray(x), -1), -1, inverse=True)
    np.testing.assert_allclose(np.asarray(y), x, rtol=1e-3, atol=1e-4 * n)


@given(st.integers(0, 2**31 - 1), st.sampled_from([6, 8, 12, 16]))
@settings(**SET)
def test_pad_fusion_identity(seed, m):
    """Rect DFT (pad fused) == DFT of explicitly padded input — the
    correctness core of the paper's staged-padding trick."""
    n = 2 * m
    x = _cx(seed, (3, m))
    fused = local_dft(jnp.asarray(x), -1, n)
    padded = local_dft(jnp.asarray(np.pad(x, ((0, 0), (0, n - m)))), -1)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(padded),
                               rtol=1e-3, atol=1e-4 * n)


@given(st.integers(2, 30))
@settings(max_examples=10, deadline=None)
def test_sphere_mask_matches_offsets(d):
    sph = SphereDomain.from_diameter(d)
    assert sph.mask().sum() == sph.npacked
    assert sph.extents == (d, d, d)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([2, 3, 4]))
@settings(**SET)
def test_fft_conv_matches_direct(seed, S, K):
    rng = np.random.default_rng(seed)
    C = 3
    x = rng.standard_normal((2, S, C)).astype(np.float32)
    w = rng.standard_normal((K, C)).astype(np.float32)
    from repro.models.layers import causal_conv1d, fft_causal_conv1d
    y1, _ = causal_conv1d(jnp.asarray(x), jnp.asarray(w))
    y2, _ = fft_causal_conv1d(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-3)


@given(st.integers(0, 1000), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_pipeline_deterministic_and_sharded(step, n_shards):
    cfg = DataConfig(vocab=100, seq=16, global_batch=8, seed=3)
    full = Pipeline(cfg, 0, 1).batch_at(step)
    parts = [Pipeline(cfg, s, n_shards).batch_at(step)["tokens"]
             for s in range(n_shards)] if 8 % n_shards == 0 else None
    if parts is not None:
        np.testing.assert_array_equal(np.concatenate(parts),
                                      full["tokens"])
    again = Pipeline(cfg, 0, 1).batch_at(step)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    np.testing.assert_array_equal(full["labels"][:, :-1],
                                  full["tokens"][:, 1:])


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_compression_error_feedback_unbiased(seed):
    """Over T steps, sum(dequantized) ≈ sum(grads): residual stays bounded."""
    from repro.optim.compression import (compress_grads, decompress_grads,
                                         init_residuals)
    rng = np.random.default_rng(seed)
    g_sum = np.zeros((16,), np.float32)
    q_sum = np.zeros((16,), np.float32)
    res = init_residuals({"g": jnp.zeros((16,))})
    for _ in range(8):
        g = rng.standard_normal(16).astype(np.float32)
        comp, res = compress_grads({"g": jnp.asarray(g)}, res)
        dq = np.asarray(decompress_grads(comp)["g"])
        g_sum += g
        q_sum += dq
    resid = np.abs(np.asarray(res["g"])).max()
    # residual is bounded by one quantization step of the last tensor
    assert np.abs(g_sum - q_sum).max() <= resid + 1e-5
    assert resid < 0.2


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]))
@settings(max_examples=10, deadline=None)
def test_straggler_monitor_flags_outliers(seed, k):
    from repro.train.trainer import StragglerMonitor
    mon = StragglerMonitor(sigma=3.0)
    rng = np.random.default_rng(seed)
    for i in range(20):
        mon.observe(i, 0.1 + 0.001 * rng.standard_normal())
    assert mon.observe(100, 10.0 * k) is True


@given(st.integers(0, 2**31 - 1),
       st.lists(st.tuples(st.floats(0.0, 0.9), st.floats(0.0, 0.9),
                          st.floats(0.0, 0.9)),
                min_size=1, max_size=6),
       st.sampled_from([0.0, 0.005, 0.02, 0.1, 0.25]),
       st.sampled_from([None, 1, 2, 3, 4]))
@settings(**SET)
def test_segmenter_padding_never_exceeds_budget(seed, shifts, budget,
                                                divisor):
    """Segmented ragged stacking invariants on randomized k-shift sets:
    ``segment_spheres`` must (a) partition the sphere list exactly, (b)
    keep every segment's *realized* padding within the budget — a
    singleton segment always realizes 0%, so a valid partition exists
    for any budget — and (c) honor the size-divisor contract (segment
    lengths divide the batch-axis size, so every segment's stacked batch
    still shards evenly)."""
    from repro.core import (kpoint_sphere, segment_padding_fraction,
                            segment_spheres)
    spheres = [kpoint_sphere(8)] + [kpoint_sphere(8, s) for s in shifts]
    segs = segment_spheres(spheres, budget, size_divisor=divisor)
    covered = sorted(i for seg in segs for i in seg)
    assert covered == list(range(len(spheres)))       # exact partition
    for seg in segs:
        assert segment_padding_fraction(spheres, seg) <= budget + 1e-9
        if divisor and divisor > 1:       # 1 shards anything: no constraint
            assert divisor % len(seg) == 0
        # segments group by descending npacked: the first element is the
        # pad target every other member is padded up to
        sizes = [spheres[i].npacked for i in seg]
        assert sizes[0] == max(sizes)


# ------------------------------------------- fused sphere-pack kernels
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 6, 8]),
       st.sampled_from([1, 2, 3]), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_fused_unpack_transform_bitwise(seed, d, nbands, nk):
    """unpack_transform ≡ unpack + plan, bitwise, over random sphere sets.

    The fused pallas route (CPU interpret, exact kernel code) against the
    composed XLA matmul oracle, through the full staged transform.
    """
    from repro.core import ProcGrid, kpoint_sphere, \
        make_stacked_planewave_pair

    rng = np.random.default_rng(seed)
    kpts = [tuple(rng.uniform(-0.5, 0.5, 3).round(2)) for _ in range(nk)]
    spheres = [kpoint_sphere(d, kp) for kp in kpts]
    grid = ProcGrid.create([1])
    inv, _ = make_stacked_planewave_pair(grid, 2 * d, spheres, nbands,
                                         backend="pallas")
    B, npm = nk * nbands, inv.npacked_max
    x = jnp.asarray(_cx(seed + 1, (B, npm)))
    fused = inv.unpack_transform(x)
    composed = inv(inv.unpack(x))
    assert inv._fused_in_parts() is not None     # the guard held
    assert float(jnp.abs(fused - composed).max()) == 0.0


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 6, 8]),
       st.sampled_from([1, 2]), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_fused_transform_pack_bitwise(seed, d, nbands, nk):
    """transform_pack ≡ plan + pack, bitwise — and padded lanes exact zero
    even when the input cube is seeded with garbage everywhere."""
    from repro.core import ProcGrid, kpoint_sphere, \
        make_stacked_planewave_pair

    rng = np.random.default_rng(seed)
    kpts = [tuple(rng.uniform(-0.5, 0.5, 3).round(2)) for _ in range(nk)]
    spheres = [kpoint_sphere(d, kp) for kp in kpts]
    grid = ProcGrid.create([1])
    n = 2 * d
    inv, fwd = make_stacked_planewave_pair(grid, n, spheres, nbands,
                                           backend="pallas")
    B = nk * nbands
    cube = jnp.asarray(_cx(seed + 2, (B, n, n, n)))
    fused = fwd.transform_pack(cube)
    composed = fwd.pack(fwd(cube))
    assert fwd._fused_out_parts() is not None    # the guard held
    assert float(jnp.abs(fused - composed).max()) == 0.0
    valid = inv.valid_lanes()
    pad = ~np.repeat(valid, nbands, axis=0)
    assert np.all(np.asarray(fused)[pad] == 0.0)
