"""Optimizer vs a plain-numpy AdamW reference; schedule; compression."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.optim import adamw


def _np_adamw(p, g, m, v, t, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    delta = mh / (np.sqrt(vh) + eps) + wd * p
    return p - lr * delta, m, v


def test_adamw_matches_numpy_over_steps():
    cfg = adamw.AdamWConfig(lr=1e-2, beta1=0.9, beta2=0.95, eps=1e-8,
                            weight_decay=0.01, clip_norm=1e9,
                            warmup_steps=0, total_steps=10**9,
                            min_lr_frac=1.0)
    rng = np.random.default_rng(0)
    p_np = rng.standard_normal((4, 4)).astype(np.float32)
    params = {"w": jnp.asarray(p_np)}
    state = adamw.init_state(params)
    m = np.zeros_like(p_np)
    v = np.zeros_like(p_np)
    p_ref = p_np.copy()
    for t in range(1, 6):
        g_np = rng.standard_normal((4, 4)).astype(np.float32)
        params, state, _ = adamw.apply_updates(
            params, {"w": jnp.asarray(g_np)}, state, cfg)
        p_ref, m, v = _np_adamw(p_ref, g_np, m, v, t, 1e-2, 0.9, 0.95,
                                1e-8, 0.01)
        np.testing.assert_allclose(np.asarray(params["w"]), p_ref,
                                   rtol=1e-5, atol=1e-6)


def test_clipping_caps_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                            weight_decay=0.0, min_lr_frac=1.0)
    params = {"w": jnp.zeros((10,))}
    state = adamw.init_state(params)
    g = {"w": jnp.full((10,), 100.0)}
    _, _, met = adamw.apply_updates(params, g, state, cfg)
    assert float(met["grad_norm"]) > 100
    # after clipping, effective g has norm 1 → m = .1/sqrt(10) per entry


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    s = adamw.schedule(cfg, jnp.asarray(5))
    assert abs(float(s) - 0.5) < 1e-6
    s_end = adamw.schedule(cfg, jnp.asarray(110))
    assert abs(float(s_end) - 0.1) < 1e-3


def test_bf16_state_roundtrip():
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    st = adamw.init_state(params, jnp.bfloat16)
    assert st["m"]["w"].dtype == jnp.bfloat16
    cfg = adamw.AdamWConfig(warmup_steps=0)
    p2, st2, _ = adamw.apply_updates(params, {"w": jnp.ones((8, 8))}, st,
                                     cfg)
    assert st2["v"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(adamw.global_norm(t)) - np.sqrt(3 + 16)) < 1e-6
