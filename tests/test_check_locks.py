"""Seeded hazards for the runtime lock-order checker.

A two-lock ordering cycle (FFTB301) and a lock-held-across-dispatch
hazard (FFTB302) must each be caught at the moment they are created —
no actual deadlock required — and the whole machinery must cost nothing
when disabled.
"""
import threading

import pytest

from repro.check import (LockOrderError, TrackedLock, check_dispatch_hazard,
                         disable_lock_checking, enable_lock_checking,
                         lock_violations)


@pytest.fixture(autouse=True)
def _clean_monitor():
    disable_lock_checking()
    yield
    disable_lock_checking()


def test_disabled_is_a_plain_lock():
    lk = TrackedLock("a")
    assert not lk.locked()
    with lk:
        assert lk.locked()
    assert lk.acquire(blocking=False)
    lk.release()
    check_dispatch_hazard("anywhere")           # free no-op
    assert lock_violations() == []


def test_lock_order_cycle_detected_fftb301():
    enable_lock_checking(mode="raise")
    a, b = TrackedLock("a"), TrackedLock("b")
    with a:
        with b:                                  # edge a -> b
            pass
    # the reversed order closes the cycle the moment b is entered first
    with pytest.raises(LockOrderError) as exc, b:
        a.acquire()
    assert exc.value.diagnostic.code == "FFTB301"
    assert "a" in exc.value.diagnostic.message
    # the failed acquire must not leave 'a' on the held stack
    with a:
        pass


def test_lock_order_cycle_across_threads():
    enable_lock_checking(mode="record")
    x, y = TrackedLock("x"), TrackedLock("y")

    def t1():
        with x, y:
            pass

    def t2():
        with y, x:
            pass

    th1 = threading.Thread(target=t1)
    th1.start()
    th1.join(timeout=10)
    th2 = threading.Thread(target=t2)
    th2.start()
    th2.join(timeout=10)
    viol = lock_violations()
    assert [d.code for d in viol] == ["FFTB301"]
    assert "lock-order cycle" in viol[0].message


def test_record_mode_does_not_raise():
    enable_lock_checking(mode="record")
    a, b = TrackedLock("p"), TrackedLock("q")
    with a, b:
        pass
    with b:
        with a:                                  # cycle, but only recorded
            pass
    assert [d.code for d in lock_violations()] == ["FFTB301"]


def test_dispatch_hazard_fftb302():
    enable_lock_checking(mode="raise")
    lk = TrackedLock("serve.metrics")
    with pytest.raises(LockOrderError) as exc, lk:
        check_dispatch_hazard("plan_cache.build")
    assert exc.value.diagnostic.code == "FFTB302"
    assert "plan_cache.build" in exc.value.diagnostic.message
    # outside the lock the same site is fine
    check_dispatch_hazard("plan_cache.build")


def test_reentrant_lock_no_false_cycle():
    enable_lock_checking(mode="raise")
    lk = TrackedLock("cache", reentrant=True)
    with lk:
        with lk:                                 # re-entry: no new edge
            assert lk.locked()
    assert not lk.locked()
    assert lock_violations() == []


def test_same_order_many_threads_is_clean():
    enable_lock_checking(mode="record")
    outer, inner = TrackedLock("outer"), TrackedLock("inner")

    def worker():
        for _ in range(50):
            with outer, inner:
                pass

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert lock_violations() == []


def test_plan_cache_build_runs_outside_its_lock():
    """The integration the checker exists for: PlanCache must never hold
    its lock across a plan build."""
    from repro.core import PlanCache
    enable_lock_checking(mode="raise")
    cache = PlanCache(maxsize=4)

    class _P:
        def estimated_bytes(self):
            return 64

        def shared_table_bytes(self):
            return {}

    # get_or_build calls check_dispatch_hazard before the builder; a
    # lock-holding build would raise FFTB302 here
    assert cache.get_or_build("k", _P) is cache.peek("k")


def test_service_locks_are_tracked():
    from repro.serve.metrics import ServiceMetrics
    from repro.serve.scheduler import CoalescingScheduler
    assert isinstance(CoalescingScheduler()._lock, TrackedLock)
    assert isinstance(ServiceMetrics()._lock, TrackedLock)
