"""PlanCache under real contention — the transform service's hot state.

N threads hammering M distinct sphere-plan keys must behave like the
single-threaded cache: exactly one insert wins per key (everyone holds the
winner), ``resident_bytes`` never exceeds ``max_bytes`` after eviction
churn, and hits + misses account for every lookup.
"""
import threading

import pytest

from repro.check import disable_lock_checking, enable_lock_checking
from repro.core import PlanCache

N_THREADS = 8
M_KEYS = 12


@pytest.fixture(autouse=True)
def _lock_order_checking():
    """Every stress test runs with the lock-order checker armed: an
    ordering cycle or a build dispatched under the cache lock raises
    ``LockOrderError`` inside a worker and fails the test."""
    enable_lock_checking(mode="raise")
    yield
    disable_lock_checking()


class _FakePlan:
    """A plan double with the byte-accounting protocol (cheap to build)."""

    def __init__(self, key, nbytes=50_000):
        self.key = key
        self.nbytes = nbytes

    def estimated_bytes(self):
        return self.nbytes

    def shared_table_bytes(self):
        # two "DFT tables" shared across every fake plan of the same size
        return {("tab", self.nbytes, False): 1000,
                ("tab", self.nbytes, True): 1000}


def _hammer(cache, keys, rounds, results, barrier, builds):
    def worker(tid):
        barrier.wait(timeout=30)
        got = {}
        for _ in range(rounds):
            for k in keys:
                def build(k=k):
                    builds.append(k)
                    return _FakePlan(k)
                got[k] = cache.get_or_build(("sphere-key", k), build)
        results[tid] = got

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert all(not t.is_alive() for t in threads)


def test_contended_no_duplicate_insert_wins():
    """All threads racing all keys: one winner per key, stats consistent."""
    cache = PlanCache(maxsize=2 * M_KEYS)
    results, builds = {}, []
    barrier = threading.Barrier(N_THREADS)
    rounds = 5
    _hammer(cache, range(M_KEYS), rounds, results, barrier, builds)
    # every thread ends holding the same (winning) object per key
    for k in range(M_KEYS):
        winners = {id(results[t][k]) for t in range(N_THREADS)}
        assert len(winners) == 1, f"key {k}: {len(winners)} distinct plans"
        assert cache.peek(("sphere-key", k)) is results[0][k]
    s = cache.stats
    lookups = N_THREADS * rounds * M_KEYS
    assert s["hits"] + s["misses"] == lookups
    # exactly one miss per key — racing losers count as hits, and losing
    # duplicate builds (len(builds) may exceed M_KEYS) were all discarded
    assert s["misses"] == M_KEYS
    assert len(builds) >= M_KEYS
    assert s["evictions"] == 0 and len(cache) == M_KEYS


def test_contended_eviction_respects_byte_budget():
    """Churn under a byte budget ~3 entries wide: the budget holds at
    every step, not just at the end."""
    plan_bytes = 50_000
    cache = PlanCache(maxsize=256, max_bytes=3 * plan_bytes + 2000)
    results, builds = {}, []
    barrier = threading.Barrier(N_THREADS)
    stop = threading.Event()
    violations = []

    def monitor():
        while not stop.is_set():
            rb = cache.resident_bytes
            if rb > cache.max_bytes:
                violations.append(rb)

    mon = threading.Thread(target=monitor)
    mon.start()
    try:
        _hammer(cache, range(M_KEYS), 4, results, barrier, builds)
    finally:
        stop.set()
        mon.join(timeout=10)
    assert not violations, f"resident_bytes exceeded budget: {violations}"
    assert cache.resident_bytes <= cache.max_bytes
    s = cache.stats
    assert s["evictions"] > 0                    # churn actually happened
    assert s["hits"] + s["misses"] == N_THREADS * 4 * M_KEYS
    # every key was (re)built at least once; evicted keys re-miss
    assert s["misses"] >= M_KEYS
    assert len(cache) <= 3 + 1                   # ~budget ÷ entry size


def test_peek_is_side_effect_free():
    cache = PlanCache()
    assert cache.peek("cold") is None
    p = cache.get_or_build("k", lambda: _FakePlan("k"))
    s0 = cache.stats
    assert cache.peek("k") is p
    assert cache.peek("cold") is None
    assert cache.stats == s0                     # no hit/miss/LRU movement


def test_stress_entry_count_cap_still_enforced():
    """maxsize (entry-count ceiling) holds under the same contention."""
    cache = PlanCache(maxsize=4)
    results = {}
    barrier = threading.Barrier(N_THREADS)
    _hammer(cache, range(M_KEYS), 2, results, barrier, [])
    assert len(cache) <= 4
    assert cache.stats["evictions"] >= M_KEYS - 4
