"""FFTB core: descriptor API, planner, distributed 3D FFTs (Table 1 rows)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Domain, DistTensor, ExecPolicy, ProcGrid, fftb,
                        parse_dims)
from repro.core.layout import Move, apply_move, plan_redistribution
from repro.core.plan import FFTStage, MoveStage


# ---------------------------------------------------------------- parsing
def test_parse_dims():
    names, dist = parse_dims("b x{0} y{1,2} z")
    assert names == ("b", "x", "y", "z")
    assert dist == {"x": (0,), "y": (1, 2)}


def test_parse_dims_rejects_bad_tokens():
    with pytest.raises(ValueError):
        parse_dims("x{a}")
    with pytest.raises(ValueError):
        parse_dims("x x")


def test_dtensor_shape_and_pspec():
    g = ProcGrid.create([1])
    b = Domain((0,), (3,))
    dom = Domain((0, 0, 0), (7, 7, 7))
    t = DistTensor.create((b, dom), "b x{0} y z", g)
    assert t.shape == (4, 8, 8, 8)
    assert t.pspec == jax.sharding.PartitionSpec(None, "g0", None, None)
    assert t.local_shape == (4, 8, 8, 8)


def test_dtensor_rank_mismatch():
    g = ProcGrid.create([1])
    with pytest.raises(ValueError):
        DistTensor.create(Domain((0, 0), (7, 7)), "x y z", g)


# ---------------------------------------------------------------- layout
def test_layout_moves_preserve_minor_end_invariant():
    lay = {"x": (0, 1)}
    with pytest.raises(ValueError):
        apply_move(lay, Move(0, "x", "y"))      # 0 is major, not minor
    out = apply_move(lay, Move(1, "x", "y"))
    assert out == {"x": (0,), "y": (1,)}


def test_plan_redistribution_slab_roundtrip():
    sizes = {"x": 16, "y": 16, "z": 16}
    moves = plan_redistribution({"x": (0,)}, {"z": (0,)}, sizes, (4,))
    assert moves == [Move(0, "x", "z")]


# ------------------------------------------------------- plan structure
def _mk_plan(grid_shape, spec, n=16, nb=4):
    g = ProcGrid.create_abstract(list(grid_shape))
    b = Domain((0,), (nb - 1,))
    dom = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
    return fftb(spec, domains=(b, dom), grid=g)


def test_slab_pencil_plan_has_one_transpose():
    plan = _mk_plan((4,), "b x{0} y z -> b X Y Z{0}")
    moves = [s for s in plan.stages if isinstance(s, MoveStage)]
    ffts = [s for s in plan.stages if isinstance(s, FFTStage)]
    assert len(moves) == 1 and len(ffts) == 3


def test_pencil_pencil_plan_has_two_transposes():
    plan = _mk_plan((2, 2), "b x{0} y{1} z -> b X Y{0} Z{1}")
    moves = [s for s in plan.stages if isinstance(s, MoveStage)]
    assert len(moves) == 2


def test_comm_stats_volume_slab():
    plan = _mk_plan((4,), "b x{0} y z -> b X Y Z{0}")
    (st,) = plan.comm_stats()
    # local block 4·(16/4)·16·16 complex64 → bytes·(p-1)/p leave the device
    local = 4 * 4 * 16 * 16 * 8
    assert st["bytes_per_device"] == local * 3 // 4


def test_flop_count_matmul_backend():
    plan = _mk_plan((1,), "b x{0} y z -> b X Y Z{0}")  # abstract 1-proc
    # 3 stages × 8·n·n flops per line × n² lines × nb batches
    assert plan.flop_count() == 3 * 8 * 16 * 16 * (16 * 16) * 4


# --------------------------------------------------- numerical (1 device)
def test_fft_1device_matches_numpy():
    g = ProcGrid.create([1])
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (7, 7, 7))
    plan = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g)
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((2, 8, 8, 8))
         + 1j * rng.standard_normal((2, 8, 8, 8))).astype(np.complex64)
    y = np.asarray(plan(jnp.asarray(x)))
    ref = np.fft.fftn(x, axes=(1, 2, 3))
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)


def test_inverse_fft_1device():
    g = ProcGrid.create([1])
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (7, 7, 7))
    plan = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g,
                inverse=True)
    rng = np.random.default_rng(1)
    x = (rng.standard_normal((2, 8, 8, 8))
         + 1j * rng.standard_normal((2, 8, 8, 8))).astype(np.complex64)
    y = np.asarray(plan(jnp.asarray(x)))
    ref = np.fft.ifftn(x, axes=(1, 2, 3))
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------- legacy positional API
def test_legacy_positional_fftb_removed_with_migration_hint():
    """The deprecated C++-style signature (PR 1's two-PR grace window has
    elapsed) now raises a TypeError that carries the migration recipe —
    never silently misinterprets the positional arguments."""
    g = ProcGrid.create_abstract([4])
    b = Domain((0,), (3,))
    dom = Domain((0, 0, 0), (15, 15, 15))
    ti = DistTensor.create((b, dom), "b x{0} y z", g)
    to = DistTensor.create((b, dom), "B X Y Z{0}", g)
    with pytest.raises(TypeError, match="has been removed"):
        fftb((16, 16, 16), to, "X Y Z", ti, "x y z", g)
    with pytest.raises(TypeError, match="arrow spec"):
        fftb((16, 16, 16), to, "X Y Z", ti, "x y z", g)
    # the arrow-spec builder the hint points at works for the same plan
    new = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g)
    assert new.tin.shape == (4, 16, 16, 16)


# ------------------------------------------------ distributed (subprocess)
_DIST_TMPL = """
import numpy as np, jax.numpy as jnp
from repro.core import ProcGrid, Domain, fftb
g = ProcGrid.create({grid})
n, nb = 16, 4
b = Domain((0,), (nb-1,)); dom = Domain((0,0,0),(n-1,n-1,n-1))
fx = fftb({spec!r}, domains=(b, dom), grid=g)
rng = np.random.default_rng(0)
x = (rng.standard_normal((nb,n,n,n)) + 1j*rng.standard_normal((nb,n,n,n))).astype(np.complex64)
y = np.asarray(fx(jnp.asarray(x)))
ref = np.fft.fftn(x, axes=(1,2,3))
err = np.abs(y-ref).max() / np.abs(ref).max()
assert err < 2e-6, err
print("OK", err)
"""


@pytest.mark.parametrize("grid,spec", [
    ([8], "b x{0} y z -> b X Y Z{0}"),                    # slab-pencil, 1D
    ([4, 2], "b x{0} y{1} z -> b X Y{0} Z{1}"),           # pencil, 2D
    ([2, 2, 2], "b x{0} y{1} z{2} -> b X{0} Y{1} Z{2}"),  # volumetric, 3D
    ([4], "b{0} x y z -> b{0} X Y Z"),                    # pure batch parallel
])
def test_distributed_fft_grids(dist, grid, spec):
    out = dist(_DIST_TMPL.format(grid=grid, spec=spec))
    assert "OK" in out


def test_batched_vs_unbatched_same_result(dist):
    # paper Fig. 9: batching changes the schedule, never the numbers
    script = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ProcGrid, Domain, fftb
g = ProcGrid.create([8])
n, nb = 16, 4
b = Domain((0,), (nb-1,)); dom = Domain((0,0,0),(n-1,n-1,n-1))
fx = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g)
f1 = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g)
rng = np.random.default_rng(0)
x = (rng.standard_normal((nb,n,n,n)) + 1j*rng.standard_normal((nb,n,n,n))).astype(np.complex64)
yb = np.asarray(fx(jnp.asarray(x)))
yu = np.stack([np.asarray(f1(jnp.asarray(x[i]))) for i in range(nb)])
assert np.abs(yb-yu).max() < 1e-5
print("OK")
"""
    assert "OK" in dist(script)


# ----------------------------------------------- executor modes (§Perf)
def test_lazy_executor_matches_eager():
    g = ProcGrid.create([1])
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (15, 15, 15))
    plan = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g)
    rng = np.random.default_rng(3)
    x = jnp.asarray((rng.standard_normal((2, 16, 16, 16))
                     + 1j * rng.standard_normal((2, 16, 16, 16))
                     ).astype(np.complex64))
    ye = np.asarray(plan(x))
    yl = np.asarray(plan(x, policy=ExecPolicy(mode="lazy")))
    np.testing.assert_allclose(yl, ye, rtol=1e-4, atol=1e-3)


def test_lazy_bf16_executor_precision_bounded():
    g = ProcGrid.create([1])
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (15, 15, 15))
    plan = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g)
    rng = np.random.default_rng(4)
    x = jnp.asarray((rng.standard_normal((2, 16, 16, 16))
                     + 1j * rng.standard_normal((2, 16, 16, 16))
                     ).astype(np.complex64))
    ye = np.asarray(plan(x))
    yb = np.asarray(plan(x, policy=ExecPolicy.from_mode("lazy_bf16")))
    rel = np.abs(yb - ye).max() / np.abs(ye).max()
    assert rel < 3e-2, rel          # bf16 storage, f32 accumulation


def test_lazy_executor_distributed(dist):
    script = """
import numpy as np, jax.numpy as jnp
from repro.core import ProcGrid, Domain, fftb
g = ProcGrid.create([8])
n, nb = 16, 4
b = Domain((0,), (nb-1,)); dom = Domain((0,0,0),(n-1,n-1,n-1))
fx = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g)
rng = np.random.default_rng(0)
x = (rng.standard_normal((nb,n,n,n)) + 1j*rng.standard_normal((nb,n,n,n))).astype(np.complex64)
ref = np.fft.fftn(x, axes=(1,2,3))
from repro.core import ExecPolicy
y = np.asarray(fx(jnp.asarray(x), policy=ExecPolicy(mode="lazy")))
assert np.abs(y-ref).max()/np.abs(ref).max() < 2e-6
print("OK")
"""
    assert "OK" in dist(script)
