"""Perf-trajectory gate plumbing: compare.py verdicts (including the
unknown-scenario skip and the schema-5 ``segments`` config key), atomic
and merged JSON writes, ``--scenarios gate`` resolution, and the
scf-2d / scf-stacked / scf-3d grid-shape pickers — pure-python, no
transforms executed (the gate-resolution test runs the cheap plan_cache
scenario only)."""
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from benchmarks.compare import compare_records  # noqa: E402
from benchmarks.compare import drifted_scenarios  # noqa: E402
from benchmarks.compare import main as compare_main  # noqa: E402
from benchmarks.compare import unknown_scenarios  # noqa: E402
from benchmarks.run import (atomic_json_dump,  # noqa: E402
                            require_stacked_route, scf_2d_grid_shape,
                            scf_3d_grid_shape, scf_stacked_grid_shape,
                            write_scenario_records)
from benchmarks.run import main as run_main  # noqa: E402


def _record(tps=200.0, grid=(4,), converged=True, devices=4,
            band_update="per-k"):
    return {
        "scenario": {"n": 16, "nbands": 4, "devices": devices,
                     "quick": True},
        "grid_shape": list(grid),
        "band_update": band_update,
        "converged": converged,
        "transforms_per_s": tps,
    }


# ---------------------------------------------------------------- verdicts
def test_gate_passes_within_tolerance():
    base = {"scf": _record(200.0), "scf-2d": _record(230.0, grid=(2, 2))}
    cur = {"scf": _record(165.0), "scf-2d": _record(250.0, grid=(2, 2))}
    assert compare_records(cur, base, tolerance=0.20) == []


def test_gate_fails_on_regression():
    base = {"scf": _record(200.0)}
    cur = {"scf": _record(150.0)}          # -25% < -20% tolerance
    failures = compare_records(cur, base, tolerance=0.20)
    assert len(failures) == 1 and "regressed" in failures[0]
    assert compare_records(cur, base, tolerance=0.30) == []


def test_gate_fails_on_missing_scenario_and_nonconvergence():
    base = {"scf": _record(), "scf-2d": _record(grid=(2, 2))}
    cur = {"scf": _record(converged=False)}
    failures = compare_records(cur, base)
    assert any("missing" in f for f in failures)
    assert any("did not converge" in f for f in failures)


def test_gate_fails_on_config_mismatch():
    base = {"scf": _record(grid=(4,))}
    cur = {"scf": _record(250.0, grid=(2, 2))}   # faster but different grid
    failures = compare_records(cur, base)
    assert any("grid_shape changed" in f for f in failures)
    cur2 = {"scf": _record(250.0, devices=8)}
    assert any("scenario changed" in f
               for f in compare_records(cur2, base))
    # route fields gate too: a scenario that switched from the pipelined
    # to the stacked H apply is a different configuration, not a speedup
    base3 = {"scf-2d": dict(_record(grid=(2, 2)), stacked=False)}
    cur3 = {"scf-2d": dict(_record(400.0, grid=(2, 2)), stacked=True)}
    assert any("stacked changed" in f
               for f in compare_records(cur3, base3))
    # … and a silent band-update fallback (stacked engine → per-k loop)
    # is caught the same way, even at *higher* measured throughput
    base4 = {"scf-stacked": _record(grid=(2, 2), band_update="stacked")}
    cur4 = {"scf-stacked": _record(500.0, grid=(2, 2),
                                   band_update="per-k")}
    assert any("band_update changed" in f
               for f in compare_records(cur4, base4))


def test_require_stacked_route_refuses_fallback_records():
    """scf-stacked/scf-jit must refuse to emit a per-k record — a silent
    fallback would be gated against stacked baselines."""
    rec = _record(grid=(2, 2), band_update="stacked")
    assert require_stacked_route(rec, "scf-stacked") is rec
    with pytest.raises(SystemExit, match="band-update route"):
        require_stacked_route(_record(grid=(2, 2)), "scf-stacked")


def test_gate_extra_current_scenarios_are_fine():
    """A scenario absent from the baseline (e.g. freshly added scf-stacked)
    is reported by unknown_scenarios and skipped — never a KeyError, never
    a failure; regressions in known scenarios still gate."""
    base = {"scf": _record()}
    cur = {"scf": _record(),
           "scf-stacked": _record(400.0, grid=(2, 2))}
    assert compare_records(cur, base) == []
    assert unknown_scenarios(cur, base) == ["scf-stacked"]
    # a regression in a *known* scenario still fails despite the extras
    cur_bad = dict(cur, scf=_record(100.0))
    assert any("regressed" in f for f in compare_records(cur_bad, base))
    assert unknown_scenarios(cur_bad, base) == ["scf-stacked"]


def test_gate_missing_tps_is_failure_not_keyerror():
    """Hand-edited or legacy records without transforms_per_s must produce
    an actionable gate failure, not an uncaught KeyError."""
    base = {"scf": _record()}
    broken = _record()
    del broken["transforms_per_s"]
    failures = compare_records({"scf": broken}, base)
    assert any("transforms_per_s" in f for f in failures)
    failures = compare_records({"scf": _record()}, {"scf": broken})
    assert any("transforms_per_s" in f for f in failures)


def _serve_record(rps=40.0, p99=400.0, tps=60.0, converged=True):
    return {
        "scenario": {"n": 16, "d": 8, "tenants": 3, "requests": 24,
                     "devices": 4, "quick": True},
        "grid_shape": [4],
        "band_update": "coalesced",
        "converged": converged,
        "transforms_per_s": tps,
        "requests_per_s": rps,
        "latency_p99_ms": p99,
    }


def test_gate_serve_requests_per_s_regression():
    """serve-transform baselines gate requests/s like transforms/s."""
    base = {"serve-transform": _serve_record(40.0)}
    assert compare_records({"serve-transform": _serve_record(34.0)},
                           base, tolerance=0.20) == []
    failures = compare_records({"serve-transform": _serve_record(30.0)},
                               base, tolerance=0.20)   # -25%
    assert len(failures) == 1
    assert "requests/s regressed" in failures[0]


def test_gate_serve_p99_latency_regression_at_double_tolerance():
    """Latency gates lower-is-better at 2× the throughput tolerance."""
    base = {"serve-transform": _serve_record(p99=400.0)}
    # +30% p99 is inside the 2×20% latency window
    assert compare_records({"serve-transform": _serve_record(p99=520.0)},
                           base, tolerance=0.20) == []
    failures = compare_records({"serve-transform": _serve_record(p99=600.0)},
                               base, tolerance=0.20)   # +50% > +40%
    assert len(failures) == 1
    assert "p99 latency" in failures[0] and "regressed" in failures[0]
    # faster-than-baseline latency is never a failure
    assert compare_records({"serve-transform": _serve_record(p99=100.0)},
                           base, tolerance=0.20) == []


def test_gate_serve_metrics_missing_from_current_is_failure():
    """A current record that dropped a baseline serving metric fails the
    gate — and SCF baselines without serving metrics are unaffected."""
    base = {"serve-transform": _serve_record()}
    broken = _serve_record()
    del broken["requests_per_s"]
    failures = compare_records({"serve-transform": broken}, base)
    assert any("requests_per_s" in f for f in failures)
    # scf records carry no serving metrics: nothing extra is demanded
    assert compare_records({"scf": _record()}, {"scf": _record()}) == []
    # and a serve metric only in the *current* record gates nothing
    assert compare_records({"scf": _serve_record(tps=200.0)},
                           {"scf": dict(_record(200.0), **{
                               "scenario": _serve_record()["scenario"],
                               "grid_shape": [4],
                               "band_update": "coalesced"})}) == []


def test_gate_serve_unhealthy_run_fails():
    """converged=False on a serve record (requests dropped/errored) fails
    exactly like a non-converged SCF."""
    base = {"serve-transform": _serve_record()}
    failures = compare_records(
        {"serve-transform": _serve_record(converged=False)}, base)
    assert any("converge" in f for f in failures)


# ------------------------------------------------------------ drift check
def test_drifted_scenarios_both_directions():
    """Drift triggers on >FRAC movement either way; config-mismatched and
    baseline-missing scenarios are the gate's business, never drift's."""
    base = {"scf": _record(200.0), "scf-2d": _record(200.0, grid=(2, 2))}
    assert drifted_scenarios({"scf": _record(215.0),
                              "scf-2d": _record(200.0, grid=(2, 2))},
                             base, 0.10) == []
    up = drifted_scenarios({"scf": _record(230.0),
                            "scf-2d": _record(200.0, grid=(2, 2))},
                           base, 0.10)
    assert [(n, round(f, 2)) for n, _, _, f in up] == [("scf", 0.15)]
    down = drifted_scenarios({"scf": _record(170.0),
                              "scf-2d": _record(200.0, grid=(2, 2))},
                             base, 0.10)
    assert down[0][0] == "scf" and down[0][3] < 0
    # a config mismatch is excluded from drift (the gate reports it)
    assert drifted_scenarios({"scf": _record(400.0, grid=(2, 2)),
                              "scf-2d": _record(200.0, grid=(2, 2))},
                             base, 0.10) == []
    # unknown/missing scenarios never drift
    assert drifted_scenarios({"scf-2d": _record(200.0, grid=(2, 2))},
                             base, 0.10) == []


# --------------------------------------------------------------- CLI paths
def _dump(path, scenarios):
    with open(path, "w") as f:
        json.dump({"schema": 3, "scenarios": scenarios}, f)


def test_compare_main_exit_codes(tmp_path, capsys):
    cur, base = tmp_path / "cur.json", tmp_path / "base.json"
    _dump(cur, {"scf": _record(200.0)})
    _dump(base, {"scf": _record(210.0)})
    assert compare_main([str(cur), str(base)]) == 0
    _dump(cur, {"scf": _record(100.0)})
    assert compare_main([str(cur), str(base)]) == 1
    assert "PERF GATE FAILED" in capsys.readouterr().out


def test_compare_main_unknown_scenario_warns_and_passes(tmp_path, capsys):
    """CLI path for the scf-stacked-before-baseline-refresh situation:
    exit 0 with a visible skip warning, not a crash or a failure."""
    cur, base = tmp_path / "cur.json", tmp_path / "base.json"
    _dump(cur, {"scf": _record(200.0),
                "scf-stacked": _record(400.0, grid=(2, 2))})
    _dump(base, {"scf": _record(210.0)})
    assert compare_main([str(cur), str(base)]) == 0
    out = capsys.readouterr().out
    assert "WARNING: scf-stacked" in out and "skipped" in out
    assert "perf gate passed" in out
    # the unknown scenario never masks a real regression in a known one
    _dump(cur, {"scf": _record(100.0),
                "scf-stacked": _record(400.0, grid=(2, 2))})
    assert compare_main([str(cur), str(base)]) == 1


def test_compare_main_check_drift_exit_codes(tmp_path, capsys):
    """The drift-automation protocol: 0 = green/no drift, 1 = gate failed
    (drift never evaluated), 2 = gate green but drifted — the scheduled
    workflow keys the baseline-refresh PR on exit 2."""
    cur, base = tmp_path / "cur.json", tmp_path / "base.json"
    _dump(base, {"scf": _record(200.0)})
    _dump(cur, {"scf": _record(205.0)})
    assert compare_main([str(cur), str(base), "--check-drift", "0.10"]) == 0
    assert "no drift" in capsys.readouterr().out
    _dump(cur, {"scf": _record(260.0)})        # +30%: gate green, drifted
    assert compare_main([str(cur), str(base), "--check-drift", "0.10"]) == 2
    out = capsys.readouterr().out
    assert "BASELINE STALE" in out and "--update-baseline" in out
    _dump(cur, {"scf": _record(100.0)})        # -50%: gate failure wins
    assert compare_main([str(cur), str(base), "--check-drift", "0.10"]) == 1
    # a scenario the baseline doesn't know is a refresh signal too —
    # the automation is what onboards freshly added benchmarks
    _dump(cur, {"scf": _record(205.0),
                "scf-new": _record(300.0, grid=(2, 2))})
    assert compare_main([str(cur), str(base), "--check-drift", "0.10"]) == 2
    assert "not in the baseline yet" in capsys.readouterr().out
    # without --check-drift the fast run still exits 0 (pure gate)
    _dump(cur, {"scf": _record(260.0)})
    assert compare_main([str(cur), str(base)]) == 0


def test_compare_main_update_baseline(tmp_path):
    cur, base = tmp_path / "cur.json", tmp_path / "base.json"
    _dump(cur, {"scf": _record(123.0)})
    _dump(base, {"scf": _record(500.0)})
    assert compare_main([str(cur), str(base), "--update-baseline"]) == 0
    refreshed = json.load(open(base))
    assert refreshed["scenarios"]["scf"]["transforms_per_s"] == 123.0
    assert compare_main([str(cur), str(base)]) == 0


def test_compare_main_rejects_legacy_schema(tmp_path):
    cur = tmp_path / "cur.json"
    with open(cur, "w") as f:
        json.dump(_record(), f)            # pre-schema-2 flat record
    with pytest.raises(SystemExit, match="schema-2"):
        compare_main([str(cur), str(cur)])


# ------------------------------------------------------------ atomic write
def test_atomic_json_dump_writes_complete_file(tmp_path):
    path = tmp_path / "BENCH_scf.json"
    atomic_json_dump({"schema": 2, "scenarios": {}}, str(path))
    assert json.load(open(path)) == {"schema": 2, "scenarios": {}}
    # overwrite keeps the file valid and leaves no temp litter behind
    atomic_json_dump({"schema": 2, "scenarios": {"scf": 1}}, str(path))
    assert json.load(open(path))["scenarios"] == {"scf": 1}
    assert os.listdir(tmp_path) == ["BENCH_scf.json"]


def test_atomic_json_dump_failure_leaves_old_contents(tmp_path):
    path = tmp_path / "BENCH_scf.json"
    atomic_json_dump({"ok": 1}, str(path))
    with pytest.raises(TypeError):
        atomic_json_dump({"bad": object()}, str(path))   # not serializable
    assert json.load(open(path)) == {"ok": 1}            # old file intact
    assert os.listdir(tmp_path) == ["BENCH_scf.json"]    # temp cleaned up


# ----------------------------------------------------------- grid pickers
def test_scf_2d_grid_shape_splits():
    """Same policy as --grid auto (choose_dft_grid_shape), scenario-sized."""
    assert scf_2d_grid_shape(4) == (2, 2)        # CI's baseline shape
    # from 8 devices the chooser's pencil tier wins ((2, 2, 2) — more fft
    # parallelism than any feasible 2D split), so the 2D scenario skips
    assert scf_2d_grid_shape(8) is None
    assert scf_2d_grid_shape(1) is None
    assert scf_2d_grid_shape(2) is None
    # device counts with no split dividing the scenario's nbands=4 /
    # diameter=8 skip gracefully instead of crashing PlaneWaveBasis
    assert scf_2d_grid_shape(6) is None          # batch factor 3 ∤ 4
    assert scf_2d_grid_shape(12) is None
    assert scf_2d_grid_shape(16) is None         # pencil (4, 2, 2) wins


def test_scf_stacked_grid_shape_requires_stackable_batch():
    """scf-stacked runs only where basis.stacks_k will hold — the batch
    factor must carry whole k-points and divide the nk·nbands batch."""
    assert scf_stacked_grid_shape(4) == (2, 2)   # pb=2: 2|2·4, 2%2==0
    assert scf_stacked_grid_shape(8) is None     # chooser goes pencil
    assert scf_stacked_grid_shape(1) is None
    assert scf_stacked_grid_shape(2) is None     # no 2D split at all
    assert scf_stacked_grid_shape(6) is None     # scf-2d infeasible too


def test_scf_3d_grid_shape_pencil():
    """scf-3d runs exactly where the chooser picks a (batch, fft, fft)
    pencil — 8 devices for the scenario shape; smaller counts or counts
    the pencil rules reject skip gracefully."""
    assert scf_3d_grid_shape(8) == (2, 2, 2)     # CI's 8-device shape
    assert scf_3d_grid_shape(16) == (4, 2, 2)
    for nd in (1, 2, 4, 6, 12):                  # chooser stays 1D/2D
        assert scf_3d_grid_shape(nd) is None
    assert scf_3d_grid_shape(7) is None          # prime → 1D


# ------------------------------------------------- segments as config key
def test_gate_segments_is_optional_config_key():
    """Schema-5 ``segments`` gates only when the baseline carries it: a
    changed segmentation executes different batched transforms (config
    mismatch), while schema-4 baselines without the field compare as
    before — the bridge that lets old baselines keep gating."""
    base4 = {"scf-3d": _record(grid=(2, 2, 2), band_update="stacked")}
    cur = {"scf-3d": dict(_record(grid=(2, 2, 2), band_update="stacked"),
                          segments=2)}
    assert compare_records(cur, base4) == []     # baseline predates field
    base5 = {"scf-3d": dict(_record(grid=(2, 2, 2),
                                    band_update="stacked"), segments=2)}
    cur_same = {"scf-3d": dict(_record(grid=(2, 2, 2),
                                       band_update="stacked"), segments=2)}
    assert compare_records(cur_same, base5) == []
    cur_resegmented = {"scf-3d": dict(
        _record(400.0, grid=(2, 2, 2), band_update="stacked"), segments=1)}
    failures = compare_records(cur_resegmented, base5)
    assert any("segments changed" in f for f in failures)
    # a segmentation mismatch is the gate's business, never drift's
    assert drifted_scenarios(cur_resegmented, base5, 0.10) == []


# -------------------------------------------------------- gate resolution
def test_run_main_gate_resolves_scenarios_from_baseline(tmp_path, capsys):
    """--scenarios gate runs exactly what the baseline gates — the single
    source of truth CI and the drift automation share.  plan_cache is the
    cheapest real scenario, so the resolution path runs end to end."""
    base = tmp_path / "base.json"
    _dump(base, {"plan_cache": _record()})
    run_main(["--scenarios", "gate", "--baseline", str(base),
              "--json-out", str(tmp_path / "out.json")])
    out = capsys.readouterr().out
    assert "gate scenarios from" in out and "plan_cache" in out
    assert "plan_build_cold" in out              # the scenario actually ran


def test_run_main_gate_rejects_unknown_only_baseline(tmp_path, capsys):
    """A baseline gating only scenarios this harness cannot run is a hard
    error (plus a visible warning), not a silent empty run."""
    base = tmp_path / "base.json"
    _dump(base, {"scf-quantum": _record()})
    with pytest.raises(SystemExit):
        run_main(["--scenarios", "gate", "--baseline", str(base)])
    assert "cannot run them" in capsys.readouterr().out
    with pytest.raises(SystemExit):              # unreadable baseline
        run_main(["--scenarios", "gate",
                  "--baseline", str(tmp_path / "missing.json")])


# ------------------------------------------------------------ merge writes
def test_write_scenario_records_merges_into_existing(tmp_path):
    """CI's two-step artifact: the 8-device scf-3d run folds into the
    4-device BENCH_scf.json (merge=True); a later record for the same
    scenario wins; without merge the file is replaced wholesale."""
    out = tmp_path / "BENCH_scf.json"
    write_scenario_records({"scf": _record(200.0)}, str(out))
    merged = write_scenario_records(
        {"scf-3d": _record(300.0, grid=(2, 2, 2), band_update="stacked")},
        str(out), merge=True)
    assert set(merged) == {"scf", "scf-3d"}
    data = json.load(open(out))
    assert data["schema"] == 5
    assert set(data["scenarios"]) == {"scf", "scf-3d"}
    assert data["scenarios"]["scf"]["transforms_per_s"] == 200.0
    # re-measuring a scenario overwrites its record in place
    write_scenario_records({"scf": _record(150.0)}, str(out), merge=True)
    data = json.load(open(out))
    assert data["scenarios"]["scf"]["transforms_per_s"] == 150.0
    assert set(data["scenarios"]) == {"scf", "scf-3d"}
    # merge against a missing file degrades to a plain write
    fresh = tmp_path / "fresh.json"
    assert set(write_scenario_records({"scf": _record()}, str(fresh),
                                      merge=True)) == {"scf"}
    # without merge, stale scenarios are dropped — a full re-run owns
    # the artifact
    write_scenario_records({"scf": _record()}, str(out))
    assert set(json.load(open(out))["scenarios"]) == {"scf"}
