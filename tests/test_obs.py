"""Unified observability layer: tracer, metrics registry, instrumentation.

The contracts under test, in the order the layer makes them:

* disabled tracing is free — ``span()`` returns a shared no-op singleton
  (no allocation) and instrumented paths record nothing;
* enabled spans nest per thread with correct depth/parent, and the
  Chrome-trace export is valid, Perfetto-shaped JSON;
* ``timed_call`` blocks on the result before stopping the clock (the
  wall-clock honesty rule the benchmark audit enforces);
* percentile/reservoir math is safe on empty and single-sample windows,
  and ``ServiceMetrics`` storage is bounded;
* the registry's probes expose the legacy counters (FftPlan.executions,
  PlanCache.stats, PERK_LINALG_CALLS) without changing their APIs;
* traced plan execution (the per-stage path) returns the same values as
  untraced execution, and the instrumented SCF loop reports per-iteration
  records.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro.obs.metrics import (MetricsRegistry, Reservoir, diff_snapshot,
                               global_metrics, percentile,
                               register_weak_probe)
from repro.obs.trace import NOOP_SPAN, Tracer, get_tracer, timed_call


@pytest.fixture(autouse=True)
def _quiet_global_tracer():
    """Tests drive the global tracer explicitly; leave it off afterwards."""
    yield
    get_tracer().disable()
    get_tracer().clear()


# ------------------------------------------------------------------ tracer
def test_disabled_span_is_shared_noop_singleton():
    tr = Tracer()
    assert not tr.enabled
    assert tr.span("a") is tr.span("b") is NOOP_SPAN
    with tr.span("outer", key=1) as sp:
        assert sp.sync(42) == 42         # passthrough, no recording
        sp.set(more=2)
    tr.event("e", 0.0, 1.0)
    tr.instant("i")
    assert tr.events() == []


def test_disabled_overhead_no_allocation():
    """The disabled fast path allocates no span objects at all."""
    tr = Tracer()
    spans = [tr.span(f"s{i}") for i in range(100)]
    assert all(s is NOOP_SPAN for s in spans)


def test_spans_nest_with_depth_and_parent():
    tr = Tracer().enable(sync=False)
    with tr.span("outer"):
        with tr.span("inner"):
            with tr.span("leaf", tag="x"):
                pass
    evs = {e["name"]: e for e in tr.events()}
    assert evs["outer"]["depth"] == 0 and evs["outer"]["parent"] is None
    assert evs["inner"]["depth"] == 1 and evs["inner"]["parent"] == "outer"
    assert evs["leaf"]["depth"] == 2 and evs["leaf"]["parent"] == "inner"
    assert evs["leaf"]["attrs"] == {"tag": "x"}
    # recorded leaf-first (exit order), every t1 >= t0
    assert all(e["t1"] >= e["t0"] for e in tr.events())


def test_threads_nest_independently():
    tr = Tracer().enable(sync=False)
    errs = []

    def work(i):
        try:
            with tr.span(f"outer{i}"):
                with tr.span(f"inner{i}"):
                    time.sleep(0.002)
        except Exception as e:            # pragma: no cover - diagnostics
            errs.append(e)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    evs = tr.events()
    assert len(evs) == 8
    for i in range(4):
        inner = next(e for e in evs if e["name"] == f"inner{i}")
        # each thread's inner span nests under ITS OWN outer, depth 1 —
        # cross-thread spans never pollute another thread's stack
        assert inner["depth"] == 1 and inner["parent"] == f"outer{i}"
    assert len({e["tid"] for e in evs}) == 4


def test_ring_buffer_bounds_and_dropped_counter():
    tr = Tracer(max_events=4).enable(sync=False)
    for i in range(10):
        tr.instant(f"m{i}")
    assert len(tr.events()) == 4
    assert tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["m6", "m7", "m8", "m9"]


def test_chrome_export_is_valid_perfetto_json(tmp_path):
    tr = Tracer().enable(sync=False)
    with tr.span("outer", bytes=8192):
        with tr.span("inner"):
            pass
    path = tr.export_chrome(str(tmp_path / "trace.json"))
    with open(path) as f:
        d = json.load(f)                   # round-trips as strict JSON
    assert d["displayTimeUnit"] == "ms"
    evs = [e for e in d["traceEvents"] if e.get("ph") == "X"]
    meta = [e for e in d["traceEvents"] if e.get("ph") == "M"]
    assert meta and meta[0]["name"] == "thread_name"
    assert {e["name"] for e in evs} == {"outer", "inner"}
    for e in evs:
        assert e["ts"] >= 0 and e["dur"] >= 0          # µs, non-negative
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    inner = next(e for e in evs if e["name"] == "inner")
    outer = next(e for e in evs if e["name"] == "outer")
    assert inner["args"]["parent"] == "outer"
    assert outer["args"]["bytes"] == 8192
    # time containment: Perfetto nests inner under outer on the same tid
    assert inner["tid"] == outer["tid"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert d["otherData"]["dropped_events"] == 0


def test_summary_rollup():
    tr = Tracer().enable(sync=False)
    for _ in range(3):
        with tr.span("a"):
            pass
    with tr.span("b"):
        pass
    s = tr.summary()
    assert s["a"]["count"] == 3 and s["b"]["count"] == 1
    assert s["a"]["total_ms"] >= 0.0


# ------------------------------------------------- wall-clock honesty audit
class _SlowResult:
    """Duck-typed device value whose drain takes a visible amount of time.

    ``jax.block_until_ready`` calls ``block_until_ready()`` on objects
    that expose it, so a naive timer (stop the clock at dispatch) reads
    ~0 while the honest one reads >= the sleep.
    """

    def __init__(self, delay):
        self.delay = delay

    def block_until_ready(self):
        time.sleep(self.delay)
        return self


def test_timed_call_blocks_before_stopping_clock():
    out, seconds = timed_call(lambda: _SlowResult(0.05))
    assert isinstance(out, _SlowResult)
    assert seconds >= 0.05, (
        f"timed_call stopped the clock after {seconds * 1e3:.1f} ms — it "
        "measured dispatch, not execution")


def test_span_sync_blocks_at_exit():
    tr = Tracer().enable(sync=True)
    with tr.span("work") as sp:
        sp.sync(_SlowResult(0.05))
    (ev,) = tr.events()
    assert ev["t1"] - ev["t0"] >= 0.05


# ----------------------------------------------------------------- metrics
def test_percentile_empty_and_single_sample():
    assert percentile([], 99) == 0.0
    assert percentile([7.0], 50) == 7.0
    assert percentile([7.0], 99) == 7.0
    assert percentile([1.0, 3.0], 50) == pytest.approx(2.0)
    xs = list(np.random.default_rng(0).standard_normal(101))
    assert percentile(xs, 50) == pytest.approx(
        float(np.percentile(np.asarray(xs), 50)))
    assert percentile(xs, 99) == pytest.approx(
        float(np.percentile(np.asarray(xs), 99)))


def test_reservoir_bounds_window_keeps_alltime_count():
    r = Reservoir(maxlen=4)
    for i in range(10):
        r.record(float(i))
    assert len(r) == 4
    assert r.count == 10                   # all-time, survives wraparound
    assert r.values() == [6.0, 7.0, 8.0, 9.0]


def test_registry_instruments_and_snapshot():
    m = MetricsRegistry()
    m.counter("c").inc()
    m.counter("c").inc(2)
    m.gauge("g").set(1.5)
    for v in (1.0, 2.0, 3.0):
        m.histogram("h").record(v)
    snap = m.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == 1.5
    assert snap["histograms"]["h"]["count"] == 3
    assert snap["histograms"]["h"]["p50"] == pytest.approx(2.0)
    json.dumps(snap)                       # JSON-safe by construction


def test_probe_errors_are_contained():
    m = MetricsRegistry()

    def bad():
        raise RuntimeError("boom")

    m.register_probe("bad", bad)
    m.register_probe("good", lambda: {"x": 1})
    snap = m.snapshot()
    assert snap["good"] == {"x": 1}
    assert "error" in snap["bad"]          # never raises out of snapshot


def test_diff_snapshot_numeric_leaves():
    before = {"counters": {"c": 3}, "nested": {"a": 1.0, "s": "x"}}
    after = {"counters": {"c": 10}, "nested": {"a": 4.0, "s": "y"},
             "new": {"k": 2}}
    d = diff_snapshot(before, after)
    assert d["counters"]["c"] == 7
    assert d["nested"]["a"] == pytest.approx(3.0)
    assert d["nested"]["s"] == "y"         # non-numeric: keep after
    assert d["new"]["k"] == 2


def test_weak_probe_dies_with_object():
    m = MetricsRegistry()

    class Obj:
        def summary(self):
            return {"alive": True}

    o = Obj()
    register_weak_probe(m, "obj", o)
    assert m.snapshot()["obj"] == {"alive": True}
    del o
    import gc
    gc.collect()
    assert "obj" not in m.snapshot()       # dead probes drop out


# ------------------------------------------------- legacy counters as probes
def test_global_registry_carries_legacy_probes():
    # importing the instrumented layers registers their probes
    from repro.core import cache, plan  # noqa: F401
    from repro.dft import hamiltonian  # noqa: F401
    snap = global_metrics().snapshot()
    assert {"executions", "searches"} <= set(snap["fftb"])
    assert {"hits", "misses", "builds", "build_seconds"} <= \
        set(snap["plan_cache"])
    assert "per_k_linalg_calls" in snap["dft"]


def test_plan_cache_stats_gain_build_accounting():
    from repro.core import PlanCache
    c = PlanCache()
    c.get_or_build("k", lambda: object())
    s = c.stats
    assert s["builds"] == 1 and s["build_seconds"] >= 0.0
    c.clear()
    assert c.stats["builds"] == 0


# ------------------------------------------------------- traced == untraced
def test_traced_plan_execution_matches_untraced():
    import jax.numpy as jnp
    from repro.core import Domain, ProcGrid, fftb
    tr = get_tracer()
    g = ProcGrid.create([1])
    dom = Domain((0, 0, 0), (7, 7, 7))
    fx = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g, sizes=(8, 8, 8))
    rng = np.random.default_rng(3)
    x = jnp.asarray((rng.standard_normal((8, 8, 8))
                     + 1j * rng.standard_normal((8, 8, 8))
                     ).astype(np.complex64))
    ref = np.asarray(fx(x))
    tr.enable(sync=True, per_stage=True)
    traced = np.asarray(fx(x))
    tr.disable()
    np.testing.assert_allclose(traced, ref, atol=1e-5)
    names = {e["name"] for e in tr.events()}
    assert any(n.startswith("plan:") for n in names)
    # per-stage spans: at least one line-DFT stage appeared
    assert any(n.startswith(("dft[", "idft[")) for n in names)
    # stage spans nest under the plan span
    stage = next(e for e in tr.events()
                 if e["name"].startswith(("dft[", "idft[", "a2a[")))
    assert stage["parent"].startswith("plan:")


def test_scf_iteration_records():
    from repro.core import ProcGrid
    from repro.dft import SCFConfig, run_scf
    cfg = SCFConfig(n=8, nbands=2, kpts=((0, 0, 0),), max_iter=3,
                    e_tol=0.0, r_tol=0.0)     # run exactly max_iter sweeps
    res = run_scf(cfg, grid=ProcGrid.create([1]))
    recs = res.iteration_records
    assert len(recs) == res.iterations
    for i, r in enumerate(recs):
        assert r["iteration"] == i
        assert r["seconds"] >= 0.0 and r["transforms"] > 0
        assert np.isfinite(r["energy"]) and np.isfinite(r["residual"])
    assert sum(r["transforms"] for r in recs) == res.transforms


def test_service_metrics_bounded_storage():
    from repro.serve.metrics import ServiceMetrics
    m = ServiceMetrics(max_samples=8)
    for i in range(100):
        m.record_request("t", latency_s=i * 1e-3, nbands=1,
                         queue_wait_s=i * 1e-4)
    m.record_dispatch(2, 2, 0.25)
    m.record_dispatch(1, 1, 0.75)
    for _ in range(50):
        m.record_dispatch(1, 1, 0.0)       # wrap the padding window
    s = m.summary()
    assert s["requests"] == 100            # all-time count
    assert s["per_tenant"]["t"]["requests"] == 100
    assert len(m._lat["t"]) == 8           # storage stays bounded
    assert s["padding_fraction_max"] == 0.75   # max survives wraparound
    assert s["queue_wait_p99_ms"] > 0.0
    # empty + single-sample windows never divide by zero
    e = ServiceMetrics()
    se = e.summary()
    assert se["latency_p99_ms"] == 0.0 and se["padding_fraction_max"] == 0.0
    e.record_request("x", 0.002, 1)
    assert e.summary()["latency_p50_ms"] == pytest.approx(2.0)
