"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests spawn subprocesses (see dist_helper)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_distributed(script: str, n_devices: int = 8, timeout: int = 300):
    """Run a python snippet in a subprocess with N virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout,
                          check=False)
    if proc.returncode != 0:
        raise AssertionError(
            f"distributed subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def dist():
    return run_distributed
