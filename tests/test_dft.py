"""repro.dft: multi-sphere k-point batches, G-space Hartree, SCF loop,
1D fft-only and 2D batch×fft processing grids, pipelined k-point updates."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (FftPlan, PlaneWaveFFT, ProcGrid, SphereDomain,
                        StackedPlaneWaveFFT, global_plan_cache)
from repro.dft import (HartreeSolver, PlaneWaveBasis, SCFConfig,
                       density_from_orbitals, run_scf)
from repro.dft.density import electron_count
from repro.dft.hamiltonian import (apply_hamiltonian,
                                   apply_hamiltonian_pipelined,
                                   apply_hamiltonian_stacked,
                                   orthonormalize, update_bands,
                                   update_bands_all_k)
from repro.dft.scf import AndersonMixer

KPTS2 = ((0.0, 0.0, 0.0), (0.5, 0.5, 0.5))


@pytest.fixture(scope="module")
def g1():
    return ProcGrid.create([1], ["dft_g"])


@pytest.fixture(scope="module")
def basis2(g1):
    return PlaneWaveBasis(16, kpts=KPTS2, nbands=3, grid=g1)


def _rand_bands(rng, nb, npk):
    c = (rng.standard_normal((nb, npk))
         + 1j * rng.standard_normal((nb, npk))).astype(np.complex64)
    return orthonormalize(jnp.asarray(c))


# -------------------------------------------------------------------- basis
def test_basis_builds_one_sphere_per_kpoint(basis2):
    s0, s1 = basis2.spheres
    assert isinstance(s0, SphereDomain) and isinstance(s1, SphereDomain)
    assert s0.center != s1.center          # k shifts the sphere center
    assert s0.extents == s1.extents == (8, 8, 8)   # shared bounding box
    assert basis2.npacked(0) != basis2.npacked(1)  # different point sets


def test_basis_kinetic_matches_cutoff_rule(basis2):
    for ik in range(basis2.nk):
        kin = np.asarray(basis2.kinetic(ik))
        g = basis2.gvectors(ik)
        ref = 0.5 * (g ** 2).sum(1) * (2 * np.pi / basis2.L) ** 2
        np.testing.assert_allclose(kin, ref, rtol=1e-6)
        # cut-off rule: every packed wave is inside the kinetic sphere
        e_cut = 0.5 * (2 * np.pi * basis2.d / (2 * basis2.L)) ** 2
        assert kin.max() <= e_cut + 1e-6


def test_basis_distinct_spheres_distinct_plans_repeats_hit(basis2):
    cache = global_plan_cache()
    inv0, fwd0 = basis2.plans_for_k(0)
    inv1, _ = basis2.plans_for_k(1)
    assert isinstance(inv0, PlaneWaveFFT)
    assert inv0 is not inv1                # distinct spheres → distinct plans
    assert inv0.sphere is basis2.spheres[0]
    hits = cache.stats["hits"]
    inv0b, fwd0b = basis2.plans_for_k(0)   # re-request: plan-cache hit
    assert inv0b is inv0 and fwd0b is fwd0
    assert cache.stats["hits"] == hits + 1


# ------------------------------------------------------------------ hartree
def test_hartree_matches_numpy_reference(basis2):
    rng = np.random.default_rng(0)
    rho = rng.random((16, 16, 16)).astype(np.float32)
    vh = np.asarray(HartreeSolver(basis2)(jnp.asarray(rho)))
    f = np.fft.fftfreq(16, d=1.0 / 16)
    gx, gy, gz = np.meshgrid(f, f, f, indexing="ij")
    g2 = (gx ** 2 + gy ** 2 + gz ** 2) * (2 * np.pi / basis2.L) ** 2
    kern = np.where(g2 > 0, 4 * np.pi / np.where(g2 > 0, g2, 1.0), 0.0)
    ref = np.real(np.fft.ifftn(np.fft.fftn(rho) * kern))
    np.testing.assert_allclose(vh, ref, rtol=1e-4, atol=1e-5)


def test_hartree_runs_on_full_cube_plan_pair(basis2):
    fwd, inv = basis2.cube_plans()
    assert isinstance(fwd, FftPlan) and not isinstance(fwd, PlaneWaveFFT)
    assert isinstance(inv, FftPlan) and not isinstance(inv, PlaneWaveFFT)
    assert fwd.tin.shape == (16, 16, 16)
    searches = FftPlan.searches
    fwd2, inv2 = basis2.cube_plans()       # cached + derived: no re-search
    assert fwd2 is fwd and inv2 is inv
    assert FftPlan.searches == searches


# ------------------------------------------------------------------ density
def test_density_integrates_to_electron_count(basis2):
    rng = np.random.default_rng(1)
    coeffs = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    occ = np.ones((basis2.nk, basis2.nbands))
    rho = density_from_orbitals(basis2, coeffs, occ)
    assert float(rho.min()) >= 0.0
    assert abs(electron_count(basis2, rho) - basis2.nbands) < 1e-3


def test_hamiltonian_is_hermitian(basis2):
    rng = np.random.default_rng(2)
    npk = basis2.npacked(0)
    c1 = _rand_bands(rng, basis2.nbands, npk)
    c2 = _rand_bands(rng, basis2.nbands, npk)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    h1 = apply_hamiltonian(basis2, 0, c1, v)
    h2 = apply_hamiltonian(basis2, 0, c2, v)
    lhs = complex(jnp.vdot(c2, h1))        # ⟨c2|H c1⟩
    rhs = complex(jnp.vdot(h2, c1))        # ⟨H c2|c1⟩
    assert abs(lhs - rhs) < 1e-3 * max(abs(lhs), 1.0)


# ------------------------------------------------------------------- mixing
def test_anderson_mixer_fixed_point_and_history():
    mixer = AndersonMixer(alpha=0.5, history=3, warmup=1)
    rho = jnp.ones((4, 4, 4))
    for _ in range(5):
        out = mixer.mix(rho, rho)          # already self-consistent
    np.testing.assert_allclose(np.asarray(out), np.asarray(rho), atol=1e-6)
    assert len(mixer._res) == 3            # history is trimmed


def test_anderson_beats_linear_on_a_linear_model():
    """ρ* = A ρ + b: Anderson reaches the fixed point faster than linear."""
    rng = np.random.default_rng(3)
    a = 0.9 * np.eye(8) + 0.05 * rng.standard_normal((8, 8))
    b = rng.standard_normal(8)

    def residual_after(mixer, iters):
        rho = jnp.zeros((8, 1, 1))
        for _ in range(iters):
            out = jnp.asarray((a @ np.asarray(rho).ravel() + b
                               ).reshape(8, 1, 1))
            rho = mixer.mix(rho, out)
        return float(jnp.linalg.norm(
            jnp.asarray(a @ np.asarray(rho).ravel() + b).reshape(8, 1, 1)
            - rho))

    lin = residual_after(AndersonMixer(0.5, history=1, warmup=99), 12)
    and_ = residual_after(AndersonMixer(0.5, history=6, warmup=2), 12)
    assert and_ < lin * 0.5


# ------------------------------------------------------------ 2D grids
def test_basis_2d_grid_defaults_and_specs():
    """(batch, fft) convention on a 2D grid; spec strings carry the axes.

    Abstract grids suffice — construction and validation never execute."""
    g2 = ProcGrid.create_abstract([2, 2])
    b = PlaneWaveBasis(16, kpts=KPTS2, nbands=4, grid=g2)
    assert b.batch_axes == (0,) and b.fft_axes == (1,)
    assert b.batch_procs == 2 and b.fft_procs == 2
    assert b._pw_spec == "b{0} x{1} y z -> b{0} X Y Z{1}"
    assert b._cube_spec == "x y z{1} -> X Y Z{1}"
    assert b.stacks_k                      # nk=2 divides the batch axis
    # a 1D grid keeps the pinned fft-only layout (and never stacks k)
    g1 = ProcGrid.create_abstract([4])
    b1 = PlaneWaveBasis(16, kpts=KPTS2, nbands=4, grid=g1)
    assert b1.batch_axes == () and b1.fft_axes == (0,)
    assert b1._pw_spec == "b x{0} y z -> b X Y Z{0}"
    assert not b1.stacks_k


def test_basis_2d_grid_validation_errors():
    g2 = ProcGrid.create_abstract([2, 2])
    with pytest.raises(ValueError, match="nbands 3 not divisible"):
        PlaneWaveBasis(16, kpts=KPTS2, nbands=3, grid=g2)
    with pytest.raises(ValueError, match="at least one fft axis"):
        PlaneWaveBasis(16, nbands=4, grid=g2, batch_axes=(0, 1))
    with pytest.raises(ValueError, match="divide over the fft-axis"):
        PlaneWaveBasis(14, diameter=7, nbands=4, grid=g2)
    with pytest.raises(ValueError, match="must be disjoint"):
        PlaneWaveBasis(16, nbands=4, grid=g2, batch_axes=(0,),
                       fft_axes=(0,))


def test_choose_dft_grid_shape_rules():
    from repro.sharding.grids import choose_dft_grid_shape
    # few devices relative to the diameter → 1D fft grid
    assert choose_dft_grid_shape(1, nbands=4, diameter=8) == (1,)
    assert choose_dft_grid_shape(2, nbands=4, diameter=8) == (2,)
    # past the pencil limit → batch×fft split
    assert choose_dft_grid_shape(4, nbands=4, diameter=8, nk=2) == (2, 2)
    # 8 devices, d=8: the pencil tier puts 2·2 devices on the transforms
    # (beating the best single fft axis pf=2) with pb=2 on the bands
    assert choose_dft_grid_shape(8, nbands=4, diameter=8) == (2, 2, 2)
    assert choose_dft_grid_shape(8, nbands=4, diameter=8, nk=2) == (2, 2, 2)
    # the batch factor must divide nbands (a hard basis requirement):
    # k-stacking never excuses it — though the pencil tier's smaller
    # pb=2 rescues nbands=2, which the 2D-only ladder dropped to 1D
    assert choose_dft_grid_shape(8, nbands=2, diameter=8, nk=2) == (2, 2, 2)
    assert choose_dft_grid_shape(8, nbands=3, diameter=8, nk=3) == (8,)
    # no valid split → fall back to 1D (basis raises the actionable error)
    assert choose_dft_grid_shape(4, nbands=3, diameter=7) == (4,)


def test_choose_dft_grid_shape_edge_cases():
    """Prime device counts, nk not dividing the batch extent, and
    nbands < ndevices: the chooser degrades predictably — a non-stackable
    2D split when one exists (basis then runs the pipelined fallback),
    the 1D fft grid when nothing divides."""
    from repro.sharding.grids import choose_dft_grid_shape
    # prime device counts past the pencil limit: the only fft factors
    # dividing both ndevices and the diameter are 1 (and pb = ndevices
    # never divides nbands) → 1D fallback, never a crash
    for p in (5, 7, 11, 13):
        assert choose_dft_grid_shape(p, nbands=4, diameter=8) == (p,)
    # nk not dividing any feasible batch factor: the stacks_k contract is
    # unmet, but a valid (pb | nbands) split still beats 1D — the basis
    # simply runs the pipelined per-k fallback on it (stacks_k False)
    assert choose_dft_grid_shape(4, nbands=4, diameter=8, nk=3) == (2, 2)
    assert choose_dft_grid_shape(8, nbands=4, diameter=8, nk=3) == (2, 2, 2)
    b = PlaneWaveBasis(16, kpts=((0, 0, 0), (0.3, 0, 0), (0, 0.3, 0)),
                       nbands=4, grid=ProcGrid.create_abstract([2, 2]))
    assert not b.stacks_k                     # nk=3 ∤ pb=2 → fallback
    # nbands smaller than every candidate batch factor → 1D fallback
    assert choose_dft_grid_shape(8, nbands=1, diameter=8) == (8,)
    # … but the pencil tier's pb=2 keeps nbands=2 on a 3-axis split
    # where the 2D-only ladder (pf ≤ 4 ⇒ pb ∈ {4, 8, 16}) fell to 1D
    assert choose_dft_grid_shape(16, nbands=2, diameter=16, nk=2) \
        == (2, 4, 2)
    assert choose_dft_grid_shape(16, nbands=3, diameter=8, nk=2) == (16,)
    # nbands ≥ the batch factor but not divisible → still 1D
    assert choose_dft_grid_shape(4, nbands=5, diameter=8) == (4,)
    # … while a composite nbands that does divide keeps the 2D split
    assert choose_dft_grid_shape(4, nbands=6, diameter=8) == (2, 2)


# ------------------------------------------------------ pipelined k-loop
def test_pipelined_hamiltonian_matches_serial(basis2):
    rng = np.random.default_rng(7)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    blocks = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    piped = apply_hamiltonian_pipelined(basis2, blocks, v)
    for ik in range(basis2.nk):
        ref = apply_hamiltonian(basis2, ik, blocks[ik], v)
        assert float(jnp.abs(piped[ik] - ref).max()) == 0.0


def test_pipelined_band_update_matches_serial_to_1e10(basis2):
    """Acceptance: the pipelined k-loop reproduces the serial path — same
    per-k math, only the dispatch interleaving differs — so the updated
    coefficients and the density they produce match to 1e-10."""
    rng = np.random.default_rng(8)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    coeffs = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    serial, serial_eps, serial_applies = [], [], 0
    for ik in range(basis2.nk):
        c, eps, napply = update_bands(basis2, ik, coeffs[ik], v, steps=3)
        serial.append(c)
        serial_eps.append(eps)
        serial_applies += napply
    piped, piped_eps, nsweep = update_bands_all_k(basis2, coeffs, v,
                                                  steps=3)
    assert nsweep * basis2.nk == serial_applies   # same H-apply count
    for ik in range(basis2.nk):
        assert float(jnp.abs(piped[ik] - serial[ik]).max()) < 1e-10
        assert float(jnp.abs(piped_eps[ik] - serial_eps[ik]).max()) < 1e-10
    occ = np.ones((basis2.nk, basis2.nbands))
    rho_s = density_from_orbitals(basis2, serial, occ)
    rho_p = density_from_orbitals(basis2, piped, occ)
    assert float(jnp.abs(rho_p - rho_s).max()) < 1e-10


def test_scf_pipeline_flag_equivalent(basis2):
    """run_scf(pipeline=True) ≡ run_scf(pipeline=False), energy and ρ."""
    g1 = basis2.grid
    cfg = {"n": 16, "nbands": 3, "kpts": KPTS2, "max_iter": 6,
           "mix_warmup": 99}
    a = run_scf(SCFConfig(**cfg, pipeline=True), grid=g1)
    b = run_scf(SCFConfig(**cfg, pipeline=False), grid=g1)
    assert a.transforms == b.transforms
    assert abs(a.energy - b.energy) < 1e-10
    assert float(jnp.abs(a.rho - b.rho).max()) < 1e-10


# ------------------------------------------------------ stacked k batches
def test_stacked_hamiltonian_matches_pipelined_and_serial(basis2):
    """Acceptance: stacked ≡ pipelined ≡ serial H apply on ragged spheres
    (distinct npacked_k per k-point) — the stacked route pads each k to
    npacked_max but must reproduce the per-k math to 1e-10."""
    assert basis2.npacked(0) != basis2.npacked(1)   # genuinely ragged
    rng = np.random.default_rng(11)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    blocks = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    stacked = apply_hamiltonian_stacked(basis2, blocks, v)
    piped = apply_hamiltonian_pipelined(basis2, blocks, v)
    for ik in range(basis2.nk):
        ref = apply_hamiltonian(basis2, ik, blocks[ik], v)
        assert stacked[ik].shape == ref.shape       # unpadded per-k block
        assert float(jnp.abs(stacked[ik] - ref).max()) < 1e-10
        assert float(jnp.abs(piped[ik] - ref).max()) < 1e-10


def test_stacked_plans_cached_and_shared_with_density(basis2):
    """The stacked pair is one PlanCache entry; its inner d³→n³ plan IS
    the density build's stacked plan (object identity, no re-search)."""
    cache = global_plan_cache()
    inv, fwd = basis2.stacked_hamiltonian_plans()
    assert isinstance(inv, StackedPlaneWaveFFT)
    assert inv.plan is basis2.stacked_inverse_plan()
    assert fwd.plan is inv.plan.inverse()
    hits = cache.stats["hits"]
    searches = FftPlan.searches
    inv2, fwd2 = basis2.stacked_hamiltonian_plans()
    assert inv2 is inv and fwd2 is fwd
    assert cache.stats["hits"] > hits
    assert FftPlan.searches == searches


def test_stacked_padded_lanes_never_leak(basis2):
    """Garbage written into the padded lanes must not reach the packed
    outputs: unpack routes padded lanes to the dump slot, pack reads them
    from the zero slot."""
    inv, fwd = basis2.stacked_hamiltonian_plans()
    assert inv.padding_fraction > 0.0               # ragged ⇒ real padding
    rng = np.random.default_rng(12)
    blocks = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    stacked = jnp.asarray(inv.stack(blocks))
    valid = np.zeros((basis2.nk, inv.npacked_max), bool)
    for ik in range(basis2.nk):
        valid[ik, :basis2.npacked(ik)] = True
    lanes = np.repeat(valid, basis2.nbands, axis=0)  # (nk·nb, npmax)
    garbage = jnp.where(jnp.asarray(lanes), stacked,
                        jnp.asarray(1e6 + 1e6j, stacked.dtype))
    # unpack: padded-lane garbage lands in the dump slot, not the cube
    assert float(jnp.abs(inv.unpack(garbage)
                         - inv.unpack(stacked)).max()) == 0.0
    # pack after a round trip: padded lanes come out exactly zero
    out = np.asarray(inv.pack(fwd(inv(inv.unpack(garbage)))))
    assert np.abs(out[~lanes]).max() == 0.0
    # and the valid lanes round-trip to the inputs (forward ∘ inverse ≈ id)
    np.testing.assert_allclose(out[lanes], np.asarray(stacked)[lanes],
                               rtol=1e-3, atol=2e-5)


def test_stacked_band_update_matches_serial(basis2):
    """update_bands_all_k(stacked=True) reproduces the serial per-k path
    to 1e-10 — eigenvalues, coefficients, and the resulting density."""
    rng = np.random.default_rng(13)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    coeffs = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    serial, serial_eps = [], []
    for ik in range(basis2.nk):
        c, eps, _ = update_bands(basis2, ik, coeffs[ik], v, steps=3)
        serial.append(c)
        serial_eps.append(eps)
    stacked, stacked_eps, _ = update_bands_all_k(basis2, coeffs, v,
                                                 steps=3, stacked=True)
    for ik in range(basis2.nk):
        assert float(jnp.abs(stacked[ik] - serial[ik]).max()) < 1e-10
        assert float(jnp.abs(stacked_eps[ik]
                             - serial_eps[ik]).max()) < 1e-10
    occ = np.ones((basis2.nk, basis2.nbands))
    rho_s = density_from_orbitals(basis2, serial, occ)
    rho_k = density_from_orbitals(basis2, stacked, occ)
    assert float(jnp.abs(rho_k - rho_s).max()) < 1e-10


def test_stacked_band_tables_cached_and_exact(basis2):
    """The dense kinetic/mask/precond tables: padded lanes exactly zero,
    valid lanes bitwise-equal to the per-k ladders, and one PlanCache
    entry (second fetch is a hit, same object, no schedule search)."""
    cache = global_plan_cache()
    tab = basis2.stacked_band_tables()
    npm = basis2.npacked_max
    assert tab.kinetic.shape == tab.mask.shape == tab.precond.shape \
        == (basis2.nk, npm)
    for ik in range(basis2.nk):
        npk = basis2.npacked(ik)
        kin = np.asarray(basis2.kinetic(ik))
        np.testing.assert_array_equal(np.asarray(tab.kinetic[ik, :npk]),
                                      kin)
        np.testing.assert_array_equal(
            np.asarray(tab.precond[ik, :npk]),
            np.asarray(1.0 / (1.0 + basis2.kinetic(ik))))
        assert np.asarray(tab.mask[ik, :npk]).all()
        for a in (tab.kinetic, tab.mask, tab.precond):
            assert np.abs(np.asarray(a[ik, npk:])).max(initial=0.0) == 0.0
    hits = cache.stats["hits"]
    searches = FftPlan.searches
    tab2 = basis2.stacked_band_tables()
    assert tab2 is tab
    assert cache.stats["hits"] == hits + 1
    assert FftPlan.searches == searches


def test_stacked_engine_two_transforms_per_sweep_no_perk_linalg(basis2):
    """Acceptance instrumentation: one stacked band-update sweep is
    exactly two distributed transforms (one batched inverse, one batched
    forward — however many k-points ride it) and zero per-k Python
    linalg dispatches; the pipelined fallback pays 2·nk transforms and
    2·nk linalg calls per step."""
    from repro.dft import hamiltonian as H
    from repro.kernels import sphere_pack
    rng = np.random.default_rng(21)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    coeffs = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    basis2.stacked_hamiltonian_plans()          # warm the plan cache
    ex0, pk0 = FftPlan.executions, H.PERK_LINALG_CALLS
    d0 = dict(sphere_pack.DISPATCHES)
    _, _, nsweep = update_bands_all_k(basis2, coeffs, v, steps=2,
                                      stacked=True)
    assert nsweep == 4
    assert FftPlan.executions - ex0 == 2 * nsweep      # 2 per sweep
    assert H.PERK_LINALG_CALLS - pk0 == 0              # fully batched
    # the matmul route must not fire the fused pallas kernels
    assert dict(sphere_pack.DISPATCHES) == d0
    ex0, pk0 = FftPlan.executions, H.PERK_LINALG_CALLS
    update_bands_all_k(basis2, coeffs, v, steps=2, stacked=False)
    assert FftPlan.executions - ex0 == 2 * nsweep * basis2.nk
    assert H.PERK_LINALG_CALLS - pk0 == 2 * 2 * basis2.nk


# ---------------------------------------------- segmented ragged stacking
KPTS3 = ((0.0, 0.0, 0.0), (0.37, 0.21, 0.11), (0.5, 0.5, 0.5))


def test_basis_default_single_segment(basis2):
    """segment_padding=None keeps the pre-segmentation contract: one
    identity-ordered full-batch segment, pad_width == npacked_max — so
    every cache key and stacked code path is unchanged."""
    assert basis2.segment_padding is None
    assert basis2.segments == (tuple(range(basis2.nk)),)
    assert basis2.nsegments == 1
    for ik in range(basis2.nk):
        assert basis2.seg_of(ik) == 0
        assert basis2.pad_width(ik) == basis2.npacked_max


def test_basis_segmented_partition_and_budget(g1):
    """A padding budget partitions the k-points into similar-npacked
    segments whose realized padding stays under the budget; pad_width
    becomes per-segment."""
    budget = 0.02
    b = PlaneWaveBasis(16, kpts=KPTS3, nbands=3, grid=g1,
                       segment_padding=budget)
    flat = sorted(i for seg in b.segments for i in seg)
    assert flat == list(range(b.nk))        # exact partition of the k range
    assert b.nsegments >= 2                 # 3 ragged spheres under 2%
    assert len(b.segment_padding_fractions) == b.nsegments
    for s, seg in enumerate(b.segments):
        assert b.segment_padding_fractions[s] <= budget + 1e-9
        width = max(b.npacked(ik) for ik in seg)
        for ik in seg:
            assert b.seg_of(ik) == s
            assert b.pad_width(ik) == width
    # the global realized padding is a weighted mean of per-segment ones
    assert 0.0 <= b.padding_fraction <= budget + 1e-9


def test_basis_pencil_grid_specs():
    """(batch, fft, fft) pencil convention: first axis batch, the two
    trailing axes jointly decompose the transforms; the spec strings
    carry both fft mesh axes.  Abstract grids suffice — construction
    and validation never execute."""
    g3 = ProcGrid.create_abstract([2, 2, 2])
    b = PlaneWaveBasis(16, kpts=KPTS2, nbands=4, grid=g3)
    assert b.batch_axes == (0,) and b.fft_axes == (1, 2)
    assert b.batch_procs == 2 and b.fft_procs == 4
    assert b._pw_spec == "b{0} x{1,2} y z -> b{0} X Y Z{1,2}"
    assert b._cube_spec == "x y z{1,2} -> X Y Z{1,2}"
    assert b.stacks_k                       # nk=2 divides pb=2


def test_segmentation_restores_k_stacking():
    """nk=3 cannot stack as one batch on a pb=2 grid (3 ∤ 2), but
    segment sizes are constrained to divide the batch-axis size, so a
    segmented basis recovers the stacked route segment by segment."""
    g2 = ProcGrid.create_abstract([2, 2])
    kpts3 = ((0, 0, 0), (0.3, 0, 0), (0, 0.3, 0))
    b0 = PlaneWaveBasis(16, kpts=kpts3, nbands=4, grid=g2)
    assert not b0.stacks_k                  # nk=3 ∤ pb=2 → fallback
    b = PlaneWaveBasis(16, kpts=kpts3, nbands=4, grid=g2,
                       segment_padding=0.25)
    assert b.stacks_k                       # every segment shards evenly
    for seg in b.segments:
        assert b.batch_procs % len(seg) == 0
        assert (len(seg) * b.nbands) % b.batch_procs == 0


def test_segmented_stacked_bitwise_vs_perk(g1):
    """Acceptance: segmented stacked H applies and band updates are
    BITWISE equal to the per-k path — the per-k oracle pads its linalg
    to the k's segment lane width, so both routes execute identical
    GEMM contraction lengths and the f32 sums associate identically."""
    from repro.dft.density import _density_stacked
    b = PlaneWaveBasis(16, kpts=KPTS3, nbands=3, grid=g1,
                       segment_padding=0.02)
    assert b.nsegments == 2
    rng = np.random.default_rng(17)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    coeffs = [_rand_bands(rng, b.nbands, b.npacked(ik))
              for ik in range(b.nk)]
    stacked = apply_hamiltonian_stacked(b, coeffs, v)
    for ik in range(b.nk):
        ref = apply_hamiltonian(b, ik, coeffs[ik], v)
        assert float(jnp.abs(stacked[ik] - ref).max()) == 0.0
    serial, serial_eps = [], []
    for ik in range(b.nk):
        c, eps, _ = update_bands(b, ik, coeffs[ik], v, steps=3)
        serial.append(c)
        serial_eps.append(eps)
    ex0 = FftPlan.executions
    stk, stk_eps, nsweep = update_bands_all_k(b, coeffs, v, steps=3,
                                              stacked=True)
    # per-segment engine: one batched inverse + one batched forward per
    # sweep per segment
    assert FftPlan.executions - ex0 == 2 * nsweep * b.nsegments
    for ik in range(b.nk):
        assert float(jnp.abs(stk[ik] - serial[ik]).max()) == 0.0
        assert float(jnp.abs(stk_eps[ik] - serial_eps[ik]).max()) == 0.0
    # density sums per-segment contributions — summation *order* differs
    # from the per-k accumulation, so f32 noise (not bitwise) is expected
    occ = np.ones((b.nk, b.nbands))
    rho_ref = density_from_orbitals(b, serial, occ)
    rho_seg = _density_stacked(b, serial, occ)
    assert (float(jnp.abs(rho_seg - rho_ref).max())
            / float(rho_ref.max())) < 1e-6


def test_scf_jit_step_matches_eager_and_dispatches_only_at_trace(basis2):
    """Acceptance: the fused jit step reproduces the eager stacked run
    (identical f32 linear mixing ⇒ energies agree to f32 energy-reduction
    precision) and performs zero per-iteration Python transform
    dispatches — the FftPlan execution count is identical for 3- and
    6-iteration runs (trace-time only) with zero per-k linalg calls."""
    from repro.dft import hamiltonian as H
    g1 = basis2.grid
    cfg = {"n": 16, "nbands": 3, "kpts": KPTS2, "max_iter": 6,
           "mix_warmup": 99, "mix_history": 1}
    eager = run_scf(SCFConfig(**cfg, stack_k=True), grid=g1)
    ex0, pk0 = FftPlan.executions, H.PERK_LINALG_CALLS
    jit6 = run_scf(SCFConfig(**cfg, stack_k=True, jit_step=True), grid=g1)
    d6 = FftPlan.executions - ex0
    assert H.PERK_LINALG_CALLS - pk0 == 0
    assert jit6.jitted and jit6.band_update == "stacked"
    assert jit6.transforms == eager.transforms   # same analytic ledger
    assert jit6.iterations == eager.iterations == 6
    assert abs(jit6.energy - eager.energy) < 1e-4
    assert np.abs(jit6.eigenvalues - eager.eigenvalues).max() < 1e-4
    assert float(jnp.abs(jit6.rho - eager.rho).max()) \
        < 1e-4 * float(eager.rho.max())
    ex0 = FftPlan.executions
    jit3 = run_scf(SCFConfig(**dict(cfg, max_iter=3), stack_k=True,
                             jit_step=True), grid=g1)
    assert jit3.iterations == 3
    assert FftPlan.executions - ex0 == d6    # dispatches ∝ traces, not its
    # the fused step needs the stacked engine — per-k fallback is refused
    with pytest.raises(ValueError, match="jit_step=True requires"):
        run_scf(SCFConfig(**cfg, stack_k=False, jit_step=True), grid=g1)


def test_scf_jit_step_anderson_converges(basis2):
    """Full Anderson-mixed jitted SCF converges to the eager answer (the
    jitted DIIS runs in f32 against the eager mixer's f64 history, so the
    bound is mixing precision, not bitwise)."""
    res = run_scf(SCFConfig(n=16, nbands=4, kpts=KPTS2, max_iter=50,
                            stack_k=True, jit_step=True),
                  grid=basis2.grid)
    assert res.converged, (res.energies, res.residuals)
    assert res.jitted and res.stacked
    assert abs(res.energy - (-1.9197)) < 5e-3, res.energy
    for eps in res.eigenvalues:
        assert np.all(np.diff(eps) >= -1e-6)


def test_scf_stack_k_flag_equivalent(basis2):
    """run_scf(stack_k=True) ≡ run_scf(stack_k=False): forcing the ragged
    stacked H sweeps changes dispatch, not results — the pipelined path
    stays available as the equivalence oracle."""
    g1 = basis2.grid
    cfg = {"n": 16, "nbands": 3, "kpts": KPTS2, "max_iter": 6,
           "mix_warmup": 99}
    a = run_scf(SCFConfig(**cfg, stack_k=True), grid=g1)
    b = run_scf(SCFConfig(**cfg, stack_k=False), grid=g1)
    assert a.stacked and not b.stacked
    assert a.padding_fraction > 0.0 and b.padding_fraction == 0.0
    assert a.transforms == b.transforms
    assert abs(a.energy - b.energy) < 1e-10
    assert float(jnp.abs(a.rho - b.rho).max()) < 1e-10
    # forcing the stacked route without the all-k loop is contradictory —
    # refused loudly rather than silently running serial per-k
    with pytest.raises(ValueError, match="stack_k=True requires"):
        run_scf(SCFConfig(**cfg, stack_k=True, pipeline=False), grid=g1)


# ---------------------------------------------------------------------- SCF
def test_scf_converges_two_kpoints_multi_band():
    """Acceptance: 2 k-points × 4 bands converges, energy monotone after
    the mixing warm-up, per-k sphere plans served from the PlanCache, and
    the Hartree term computed via the full-cube plan pair.

    Runs on however many devices the process sees — 1 in the default CI
    job, 4 in the multi-device job (XLA_FLAGS forced device count)."""
    import jax
    grid = ProcGrid.create([jax.device_count()],
                           ["dft_scf"])        # fresh axis → cold plans
    cache = global_plan_cache()
    misses0 = cache.stats["misses"]
    cfg = SCFConfig(n=16, nbands=4, kpts=KPTS2, max_iter=50)
    res = run_scf(cfg, grid=grid)
    assert res.converged, (res.energies, res.residuals)
    de = abs(res.energies[-1] - res.energies[-2])
    assert de < cfg.e_tol
    # monotone decrease once mixing has warmed up (small f32 slack)
    tail = res.energies[cfg.mix_warmup + 1:]
    assert all(b <= a + 2e-5 for a, b in zip(tail, tail[1:])), tail
    # 2 distinct sphere plans + 1 cube plan built, everything else hits
    assert cache.stats["misses"] == misses0 + 3
    assert res.cache_stats["hits"] > 10 * res.cache_stats["misses"]
    # eigenvalues come out sorted per k
    for eps in res.eigenvalues:
        assert np.all(np.diff(eps) >= -1e-6)
    # both wells bind: lowest two bands are split by less than well depth
    assert res.energy < 0.0
    assert res.transforms > 100


def test_scf_2d_grid_4dev(dist):
    """Acceptance: SCF convergence on a 2×2 (batch×fft) grid with 4 forced
    host devices — bands sharded over the batch axis, k-points stacked
    into the ragged nk·nbands batch for both the density build and the
    Hamiltonian apply — plus stacked ≡ pipelined ≡ serial H applies and
    band updates to 1e-10 on the distributed grid, the batched engine's
    two-transforms-per-sweep / zero-per-k-linalg instrumentation, and the
    fused jit step converging on the distributed grid."""
    script = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import FftPlan, ProcGrid, global_plan_cache
from repro.dft import PlaneWaveBasis, SCFConfig, run_scf
from repro.dft import hamiltonian as Hmod
from repro.dft.density import density_from_orbitals, electron_count
from repro.dft.hamiltonian import (apply_hamiltonian,
                                   apply_hamiltonian_pipelined,
                                   apply_hamiltonian_stacked,
                                   orthonormalize, update_bands,
                                   update_bands_all_k)
assert jax.device_count() == 4
grid = ProcGrid.create([2, 2], ["dft_b", "dft_f"])
basis = PlaneWaveBasis(16, kpts=((0,0,0),(0.5,0.5,0.5)), nbands=4,
                       grid=grid)
assert basis.stacks_k
assert basis.npacked(0) != basis.npacked(1)   # ragged sphere batch
rng = np.random.default_rng(0)
coeffs = [orthonormalize(jnp.asarray(
    (rng.standard_normal((4, basis.npacked(ik)))
     + 1j*rng.standard_normal((4, basis.npacked(ik)))).astype(np.complex64)))
    for ik in range(2)]
occ = np.ones((2, 4))

# stacked (k×bands batched) density == per-k reference accumulation
rho = density_from_orbitals(basis, coeffs, occ)
ref = jnp.zeros((16,)*3, jnp.float32)
for ik in range(2):
    inv, _ = basis.plans_for_k(ik)
    psi = inv(inv.unpack(coeffs[ik]))
    f = jnp.asarray((basis.weights[ik] * occ[ik]).astype(np.float32))
    ref = ref + jnp.tensordot(f, jnp.abs(psi)**2, axes=(0, 0))
ref = ref * jnp.float32(basis.n**3 / basis.dv)
assert float(jnp.abs(rho - ref).max()) / float(ref.max()) < 1e-5
assert abs(electron_count(basis, rho) - 4.0) < 1e-3

# stacked == pipelined == serial H apply on the distributed ragged batch
v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
hs = apply_hamiltonian_stacked(basis, coeffs, v)
hp = apply_hamiltonian_pipelined(basis, coeffs, v)
for ik in range(2):
    href = apply_hamiltonian(basis, ik, coeffs[ik], v)
    assert float(jnp.abs(hs[ik] - href).max()) < 1e-10
    assert float(jnp.abs(hp[ik] - href).max()) < 1e-10

# stacked band update == serial band update — coefficients, densities AND
# eigenvalues (regression: mixed-placement eager linalg used to double the
# reported Ritz values on multi-device 2D grids; _replicated pins every
# block before the concatenates/contractions)
serial, eps_ser = [], []
for ik in range(2):
    ck, ek, _ = update_bands(basis, ik, coeffs[ik], v, steps=2)
    serial.append(ck); eps_ser.append(ek)
ex0, pk0 = FftPlan.executions, Hmod.PERK_LINALG_CALLS
stacked, eps_stk, nsweep = update_bands_all_k(basis, coeffs, v, steps=2)
# batched-engine instrumentation holds on the distributed grid too:
# each sweep is exactly two distributed transforms (one batched inverse,
# one batched forward, all nk*nbands orbitals aboard), zero per-k linalg
assert FftPlan.executions - ex0 == 2 * nsweep, FftPlan.executions - ex0
assert Hmod.PERK_LINALG_CALLS - pk0 == 0
for ik in range(2):
    assert float(jnp.abs(stacked[ik] - serial[ik]).max()) < 1e-10
    assert float(jnp.abs(eps_stk[ik] - eps_ser[ik]).max()) < 1e-10
    # Ritz values are the Rayleigh quotients of the returned bands
    hck = apply_hamiltonian(basis, ik, serial[ik], v)
    rq = np.sort(np.real(np.asarray(
        jnp.sum(jnp.conj(serial[ik]) * hck, axis=1))))
    assert np.abs(rq - np.asarray(eps_ser[ik])).max() < 1e-5
rho_s = density_from_orbitals(basis, serial, occ)
rho_k = density_from_orbitals(basis, stacked, occ)
assert float(jnp.abs(rho_k - rho_s).max()) < 1e-10

# full SCF on the 2D grid converges to the 1-device reference energy and
# rides the stacked route; everything is pre-built above except the cube
# pair, so exactly one plan-cache miss remains
cache = global_plan_cache()
misses0 = cache.stats["misses"]
cfg = SCFConfig(n=16, nbands=4, kpts=((0,0,0),(0.5,0.5,0.5)), max_iter=50)
res = run_scf(cfg, grid=grid)
assert res.converged, (res.energies, res.residuals)
assert res.grid_shape == (2, 2)
assert res.stacked and res.padding_fraction > 0.0
assert res.band_update == "stacked" and not res.jitted
assert cache.stats["misses"] == misses0 + 1   # only the cube plan is new
assert abs(res.energy - (-1.9197)) < 5e-3, res.energy

# the fused jit step on the same grid: every plan already cached (zero
# new misses), zero per-k linalg, converges to the eager stacked energy
# to mixing precision (its DIIS runs in f32)
misses1 = cache.stats["misses"]
pk0 = Hmod.PERK_LINALG_CALLS
resj = run_scf(SCFConfig(n=16, nbands=4, kpts=((0,0,0),(0.5,0.5,0.5)),
                         max_iter=50, jit_step=True), grid=grid)
assert resj.converged, (resj.energies, resj.residuals)
assert resj.jitted and resj.band_update == "stacked"
assert cache.stats["misses"] == misses1
assert Hmod.PERK_LINALG_CALLS == pk0
assert abs(resj.energy - res.energy) < 1e-3, (resj.energy, res.energy)
print("OK", res.iterations, resj.iterations, round(res.energy, 5))
"""
    out = dist(script, n_devices=4)
    assert "OK" in out


def test_scf_pencil_grid_8dev(dist):
    """Acceptance: SCF on the chooser's (2, 2, 2) batch×fft×fft pencil
    grid with 8 forced host devices — two decomposed fft axes — converges
    to the 1-device energy on the stacked route; a segmented run (tight
    padding budget → per-k segments) converges to the same energy with
    zero realized padding and rides the jit step unchanged."""
    script = """
import numpy as np, jax
from repro.dft import PlaneWaveBasis, SCFConfig, run_scf
from repro.sharding.grids import choose_dft_grid
assert jax.device_count() == 8
grid = choose_dft_grid(nbands=4, nk=2, diameter=8)
assert grid.shape == (2, 2, 2), grid.shape

basis = PlaneWaveBasis(16, kpts=((0,0,0),(0.5,0.5,0.5)), nbands=4,
                       grid=grid)
assert basis.batch_axes == (0,) and basis.fft_axes == (1, 2)
assert basis.fft_procs == 4 and basis.stacks_k

cfg = SCFConfig(n=16, nbands=4, kpts=((0,0,0),(0.5,0.5,0.5)), max_iter=50)
res = run_scf(cfg, grid=grid)
assert res.converged, (res.energies, res.residuals)
assert res.grid_shape == (2, 2, 2)
assert res.stacked and res.band_update == "stacked"
assert res.segments == 1 and res.padding_fraction > 0.0
assert abs(res.energy - (-1.9197)) < 5e-3, res.energy

# segmented: the 2% budget splits the 280/254-packed spheres into two
# per-k segments (each 0% padding); same converged energy
cfg2 = SCFConfig(n=16, nbands=4, kpts=((0,0,0),(0.5,0.5,0.5)),
                 max_iter=50, segment_padding=0.02)
res2 = run_scf(cfg2, grid=grid)
assert res2.converged, (res2.energies, res2.residuals)
assert res2.stacked and res2.band_update == "stacked"
assert res2.segments == 2
assert res2.padding_fraction == 0.0
assert tuple(res2.segment_padding_fractions) == (0.0, 0.0)
assert abs(res2.energy - res.energy) < 1e-3, (res2.energy, res.energy)

# the fused jit step on the segmented pencil basis
res3 = run_scf(SCFConfig(n=16, nbands=4, kpts=((0,0,0),(0.5,0.5,0.5)),
                         max_iter=50, segment_padding=0.02,
                         jit_step=True), grid=grid)
assert res3.converged and res3.jitted and res3.segments == 2
assert abs(res3.energy - res.energy) < 1e-3, (res3.energy, res.energy)
print("OK", res.iterations, res2.iterations, res3.iterations,
      round(res.energy, 5))
"""
    out = dist(script, n_devices=8)
    assert "OK" in out


# ---------------------------------------- fused pallas sphere-pack route
@pytest.fixture(scope="module")
def basis2_pallas(g1):
    return PlaneWaveBasis(16, kpts=KPTS2, nbands=3, grid=g1,
                          backend="pallas")


def test_stacked_hamiltonian_pallas_bitwise_vs_matmul(basis2, basis2_pallas):
    """Acceptance: the fused pallas sphere-pack route through the full
    stacked Hamiltonian apply is bitwise-equal to the composed XLA matmul
    route on the same ragged sphere batch, matches the per-k oracle to
    1e-10, and actually dispatches both fused kernels (no silent
    fallback to the composed path)."""
    from repro.kernels import sphere_pack
    assert basis2_pallas.backend == "pallas"
    inv, fwd = basis2_pallas.stacked_hamiltonian_plans()
    assert inv._fused_in_parts() is not None     # fusion guards held
    assert fwd._fused_out_parts() is not None
    rng = np.random.default_rng(11)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    blocks = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    d0 = dict(sphere_pack.DISPATCHES)
    hp = apply_hamiltonian_stacked(basis2_pallas, blocks, v)
    assert sphere_pack.DISPATCHES["unpack_dft"] == d0["unpack_dft"] + 1
    assert sphere_pack.DISPATCHES["dft_pack"] == d0["dft_pack"] + 1
    hm = apply_hamiltonian_stacked(basis2, blocks, v)
    for ik in range(basis2.nk):
        assert float(jnp.abs(hp[ik] - hm[ik]).max()) == 0.0   # bitwise
        ref = apply_hamiltonian(basis2, ik, blocks[ik], v)
        assert float(jnp.abs(hp[ik] - ref).max()) < 1e-10


def test_stacked_engine_pallas_dispatch_parity(basis2_pallas):
    """The fused kernels replace a *stage*, not a plan: one pallas band
    sweep is still exactly two plan executions (the derived remainder and
    lead plans keep the composed route's accounting) plus exactly one
    fused dispatch per direction per sweep, and zero per-k linalg."""
    from repro.dft import hamiltonian as H
    from repro.kernels import sphere_pack
    rng = np.random.default_rng(21)
    v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
    coeffs = [_rand_bands(rng, basis2_pallas.nbands,
                          basis2_pallas.npacked(ik))
              for ik in range(basis2_pallas.nk)]
    basis2_pallas.stacked_hamiltonian_plans()   # warm the plan cache
    ex0, pk0 = FftPlan.executions, H.PERK_LINALG_CALLS
    d0 = dict(sphere_pack.DISPATCHES)
    _, _, nsweep = update_bands_all_k(basis2_pallas, coeffs, v, steps=2,
                                      stacked=True)
    assert nsweep == 4
    assert FftPlan.executions - ex0 == 2 * nsweep      # parity with matmul
    assert H.PERK_LINALG_CALLS - pk0 == 0
    assert sphere_pack.DISPATCHES["unpack_dft"] - d0["unpack_dft"] == nsweep
    assert sphere_pack.DISPATCHES["dft_pack"] - d0["dft_pack"] == nsweep


def test_stacked_pack_dispatch_has_no_concatenate(basis2):
    """Satellite: the zero-slot concatenate is hoisted to table-build time
    — the per-dispatch ``pack`` trace is gather + where only, so the
    dispatch path never re-materializes the widened source each call."""
    import jax
    inv, fwd = basis2.stacked_hamiltonian_plans()
    rng = np.random.default_rng(5)
    blocks = [_rand_bands(rng, basis2.nbands, basis2.npacked(ik))
              for ik in range(basis2.nk)]
    cube = inv.unpack(jnp.asarray(inv.stack(blocks)))
    jaxpr = str(jax.make_jaxpr(inv.pack)(cube))
    assert "concatenate" not in jaxpr
    # and the fast path still zeroes the padded lanes exactly
    out = np.asarray(inv.pack(cube))
    valid = np.repeat(inv.valid_lanes(), basis2.nbands, axis=0)
    assert np.abs(out[~valid]).max(initial=0.0) == 0.0


def test_stacked_hamiltonian_pallas_4dev(dist):
    """Acceptance: fused pallas route bitwise-equal to the XLA matmul
    route through the full stacked Hamiltonian apply on a 2×2 (batch×fft)
    grid with 4 forced host devices — the pack-side lane localization +
    psum merge must reproduce the composed gather exactly — and a full
    SCF run on backend='pallas' converges to the reference energy with
    the resolved backend surfaced on the result."""
    script = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ProcGrid
from repro.dft import PlaneWaveBasis, SCFConfig, run_scf
from repro.dft.hamiltonian import (apply_hamiltonian,
                                   apply_hamiltonian_stacked,
                                   orthonormalize)
from repro.kernels import sphere_pack
assert jax.device_count() == 4
grid = ProcGrid.create([2, 2], ["pal_b", "pal_f"])
kpts = ((0,0,0),(0.5,0.5,0.5))
bp = PlaneWaveBasis(16, kpts=kpts, nbands=4, grid=grid, backend="pallas")
bm = PlaneWaveBasis(16, kpts=kpts, nbands=4, grid=grid)
assert bp.backend == "pallas" and bm.backend == "matmul"
assert bp.stacks_k and bp.npacked(0) != bp.npacked(1)
inv, fwd = bp.stacked_hamiltonian_plans()
assert inv._fused_in_parts() is not None    # fusion engages on the 2D grid
assert fwd._fused_out_parts() is not None
rng = np.random.default_rng(0)
coeffs = [orthonormalize(jnp.asarray(
    (rng.standard_normal((4, bp.npacked(ik)))
     + 1j*rng.standard_normal((4, bp.npacked(ik)))).astype(np.complex64)))
    for ik in range(2)]
v = jnp.asarray(rng.standard_normal((16, 16, 16)).astype(np.float32))
d0 = dict(sphere_pack.DISPATCHES)
hp = apply_hamiltonian_stacked(bp, coeffs, v)
assert sphere_pack.DISPATCHES["unpack_dft"] == d0["unpack_dft"] + 1
assert sphere_pack.DISPATCHES["dft_pack"] == d0["dft_pack"] + 1
hm = apply_hamiltonian_stacked(bm, coeffs, v)
for ik in range(2):
    assert float(jnp.abs(hp[ik] - hm[ik]).max()) == 0.0     # bitwise
    href = apply_hamiltonian(bm, ik, coeffs[ik], v)
    assert float(jnp.abs(hp[ik] - href).max()) < 1e-10

res = run_scf(SCFConfig(n=16, nbands=4, kpts=kpts, max_iter=50,
                        backend="pallas"), grid=grid)
assert res.converged, (res.energies, res.residuals)
assert res.backend == "pallas" and res.stacked
assert abs(res.energy - (-1.9197)) < 5e-3, res.energy
print("OK", res.iterations, round(res.energy, 5))
"""
    out = dist(script, n_devices=4)
    assert "OK" in out


def test_scf_distributed_4dev(dist):
    """Acceptance: same problem on 4 simulated devices — sphere plans from
    the cache, cube pair for Hartree, convergence to the 1-device energy."""
    script = """
from repro.core import global_plan_cache
from repro.dft import SCFConfig, run_scf
import jax
assert jax.device_count() == 4
cfg = SCFConfig(n=16, nbands=4, kpts=((0,0,0),(0.5,0.5,0.5)), max_iter=50)
res = run_scf(cfg)
assert res.converged, res.energies
assert res.cache_stats["misses"] == 3      # 2 spheres + 1 cube
assert res.cache_stats["hits"] >= 1        # repeated spheres hit the cache
assert abs(res.energy - (-1.9197)) < 5e-3, res.energy
print("OK", res.iterations, round(res.energy, 5))
"""
    out = dist(script, n_devices=4)
    assert "OK" in out
