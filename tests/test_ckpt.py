"""Checkpointing: atomicity, GC, async, elastic restore, trainer resume."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.ckpt.checkpoint import CheckpointManager


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(64.0).reshape(8, 8),
                       "b": jnp.ones((8,))},
            "step": jnp.asarray(7)}


def test_roundtrip(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(10, tree, block=True)
    step, rt = cm.restore()
    assert step == 10
    np.testing.assert_array_equal(rt["params"]["w"],
                                  np.asarray(tree["params"]["w"]))
    assert rt["step"] == 7


def test_async_save_visible_after_wait(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=2)
    cm.save(5, tree)
    cm.wait()
    assert cm.latest_step() == 5


def test_keep_k_gc(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, tree, block=True)
    assert cm.all_steps() == [3, 4]


def test_partial_write_invisible(tmp_path, tree):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, tree, block=True)
    # crash simulation: tmp dir and manifest-less dir must be ignored
    os.makedirs(tmp_path / "step_00000009.tmp")
    os.makedirs(tmp_path / "step_00000008")
    (tmp_path / "step_00000008" / "arr_0.npy").write_bytes(b"junk")
    assert cm.latest_step() == 1
    step, rt = cm.restore()
    assert step == 1


def test_elastic_restore_to_other_mesh(tmp_path, tree):
    from repro.launch.mesh import make_host_mesh
    cm = CheckpointManager(str(tmp_path))
    specs = {"params": {"w": P("data", "model"), "b": P()}, "step": P()}
    cm.save(3, tree, specs, block=True)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    step, rt = cm.restore(mesh=mesh, specs_tree=specs)
    assert rt["params"]["w"].sharding.spec == P("data", "model")
    np.testing.assert_array_equal(np.asarray(rt["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    # restore dropping an axis the new mesh lacks (elastic down-scale)
    mesh1 = make_host_mesh((1,), ("data",))
    step, rt1 = cm.restore(mesh=mesh1, specs_tree=specs)
    np.testing.assert_array_equal(np.asarray(rt1["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_trainer_resumes_from_checkpoint(tmp_path):
    """Kill training mid-run; a fresh Trainer must continue, not restart."""
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.models.model_zoo import build
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = get_config("tinyllama-1.1b").reduced()
    bundle = build(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq=16, global_batch=2)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    tcfg = TrainerConfig(total_steps=4, ckpt_every=2, log_every=100,
                         ckpt_dir=str(tmp_path))
    t1 = Trainer(bundle, ocfg, tcfg, dcfg)
    t1.run()
    assert t1.ckpt.latest_step() == 4
    # second trainer: resumes at 4, runs to 6
    tcfg2 = TrainerConfig(total_steps=6, ckpt_every=2, log_every=100,
                          ckpt_dir=str(tmp_path))
    t2 = Trainer(bundle, ocfg, tcfg2, dcfg)
    t2.run()
    assert t2.history[0]["step"] == 4
    assert t2.ckpt.latest_step() == 6


def test_trainer_preemption_checkpoint(tmp_path):
    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.models.model_zoo import build
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = get_config("tinyllama-1.1b").reduced()
    bundle = build(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq=16, global_batch=2)
    tcfg = TrainerConfig(total_steps=100, ckpt_every=1000, log_every=1000,
                         ckpt_dir=str(tmp_path))
    t = Trainer(bundle, AdamWConfig(warmup_steps=0), tcfg, dcfg)
    t._stop = True                      # simulate SIGTERM delivery
    t.run()
    # stopped after step 0 but still committed a checkpoint
    assert t.ckpt.latest_step() == 1
    assert len(t.history) == 1
