"""Arrow-spec builder API: Transform.parse, ExecPolicy, plan derivation
(inverse/adjoint without re-planning), and the process-global PlanCache."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (Domain, ExecPolicy, FftPlan, PlanCache, ProcGrid,
                        SphereDomain, Transform, dims_string, fftb,
                        global_plan_cache, parse_dims, parse_transform_spec)


@pytest.fixture()
def g1():
    return ProcGrid.create([1])


def _rand_c64(rng, shape):
    return (rng.standard_normal(shape)
            + 1j * rng.standard_normal(shape)).astype(np.complex64)


# ------------------------------------------------------------ arrow parsing
def test_parse_dims_dims_string_roundtrip():
    spec = "b x{0} y{1,2} z"
    names, dist = parse_dims(spec)
    assert dims_string(names, dist) == spec
    names2, dist2 = parse_dims(dims_string(names, dist))
    assert (names2, dist2) == (names, dist)


def test_parse_transform_spec_splits_on_arrow():
    (inn, ind), (outn, outd) = parse_transform_spec(
        "b x{0} y z -> b X Y Z{0}")
    assert inn == ("b", "x", "y", "z")
    assert ind == {"x": (0,)}
    assert outn == ("b", "X", "Y", "Z")
    assert outd == {"Z": (0,)}


def test_transform_parse_pairs_and_batch():
    tr = Transform.parse("b x{0} y z -> b X Y Z{0}")
    assert tr.fft_pairs == [("x", "X"), ("y", "Y"), ("z", "Z")]
    assert tr.batch_dims == ("b",)
    assert tr.in_spec == "b x{0} y z"
    assert tr.out_spec == "b X Y Z{0}"


@pytest.mark.parametrize("bad", [
    "b x y z",                       # no arrow
    "x y -> X",                      # rank mismatch
    "x y -> X Y -> Z W",             # two arrows
    "x x -> X Y",                    # duplicate dim
    "x{+} y -> X Y",                 # bad token
    "b x -> b x",                    # nothing transformed
    " -> X Y",                       # empty side
])
def test_parse_transform_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_transform_spec(bad)


def test_parse_dims_rejects_arrow():
    with pytest.raises(ValueError):
        parse_dims("x -> X")


def test_build_rejects_bad_grid_axis(g1):
    dom = Domain((0, 0, 0), (7, 7, 7))
    with pytest.raises(ValueError):
        # grid axis 1 does not exist on a 1D grid
        fftb("x{1} y z -> X Y Z{1}", domains=dom, grid=g1)


def test_build_rejects_rank_mismatch(g1):
    dom = Domain((0, 0), (7, 7))
    with pytest.raises(ValueError):
        fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1)


def test_transform_is_hashable_and_reusable(g1):
    tr = Transform.parse("x{0} y z -> X Y Z{0}")
    assert hash(tr) == hash(Transform.parse("x{0} y z -> X Y Z{0}"))
    p8 = tr.build(Domain((0, 0, 0), (7, 7, 7)), g1)
    p16 = tr.build(Domain((0, 0, 0), (15, 15, 15)), g1)
    assert p8.tin.shape == (8, 8, 8) and p16.tin.shape == (16, 16, 16)


# ----------------------------------------------------- acceptance: builder
def test_fftb_apply_regular_grid(g1):
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (7, 7, 7))
    rng = np.random.default_rng(0)
    x = _rand_c64(rng, (2, 8, 8, 8))
    y = np.asarray(fftb.apply("b x{0} y z -> b X Y Z{0}", jnp.asarray(x),
                              domains=(b, dom), grid=g1))
    ref = np.fft.fftn(x, axes=(1, 2, 3))
    np.testing.assert_allclose(y, ref, rtol=2e-4, atol=2e-4)


def test_fftb_apply_sphere_batch(g1):
    """Sphere input domain selects the plane-wave staged-padding path."""
    sph = SphereDomain.from_diameter(8)
    b = Domain((0,), (1,))
    n = 16
    plan = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, sph), grid=g1,
                sizes=(n, n, n), inverse=True)
    from repro.core import PlaneWaveFFT
    assert isinstance(plan, PlaneWaveFFT)
    rng = np.random.default_rng(1)
    packed = _rand_c64(rng, (2, sph.npacked))
    cube = np.asarray(plan.unpack(jnp.asarray(packed)))
    full = np.zeros((2, n, n, n), np.complex64)
    full[:, :8, :8, :8] = cube
    ref = np.fft.ifftn(full, axes=(1, 2, 3))
    y = np.asarray(plan(jnp.asarray(cube)))
    np.testing.assert_allclose(y, ref, rtol=3e-4, atol=1e-6)
    # and the cached-apply form produces the same numbers
    y2 = np.asarray(fftb.apply("b x{0} y z -> b X Y Z{0}",
                               jnp.asarray(cube), domains=(b, sph),
                               grid=g1, sizes=(n, n, n), inverse=True))
    np.testing.assert_allclose(y2, y, rtol=0, atol=0)


# ------------------------------------------------- derived inverse/adjoint
def test_inverse_roundtrip_without_replanning(g1):
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (7, 7, 7))
    plan = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g1)
    before = FftPlan.searches
    inv = plan.inverse()
    assert FftPlan.searches == before, "inverse() ran a schedule search"
    rng = np.random.default_rng(2)
    x = _rand_c64(rng, (2, 8, 8, 8))
    rt = np.asarray(inv(plan(jnp.asarray(x))))
    np.testing.assert_allclose(rt, x, rtol=1e-4, atol=1e-5)
    # the derived plan maps tout back onto tin
    assert inv.tin is plan.tout and inv.tout is plan.tin


def test_double_inverse_is_original_transform(g1):
    dom = Domain((0, 0, 0), (7, 7, 7))
    plan = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1)
    again = plan.inverse().inverse()
    assert again is plan               # memoized + back-linked
    assert plan.inverse() is plan.inverse()
    rng = np.random.default_rng(5)
    x = _rand_c64(rng, (8, 8, 8))
    np.testing.assert_allclose(np.asarray(again(jnp.asarray(x))),
                               np.asarray(plan(jnp.asarray(x))),
                               rtol=1e-4, atol=1e-4)


def test_adjoint_inner_product_identity(g1):
    """<F x, y> == <x, F^H y> for the derived adjoint."""
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (7, 7, 7))
    plan = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g1)
    before = FftPlan.searches
    adj = plan.adjoint()
    assert FftPlan.searches == before, "adjoint() ran a schedule search"
    rng = np.random.default_rng(3)
    x = _rand_c64(rng, (2, 8, 8, 8))
    y = _rand_c64(rng, (2, 8, 8, 8))
    lhs = np.vdot(np.asarray(plan(jnp.asarray(x))), y)
    rhs = np.vdot(x, np.asarray(adj(jnp.asarray(y))))
    assert abs(lhs - rhs) / abs(lhs) < 1e-4


def test_adjoint_of_forward_fft_is_scaled_inverse(g1):
    """For the unnormalized DFT, F^H = n³ · F⁻¹."""
    dom = Domain((0, 0, 0), (7, 7, 7))
    plan = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1)
    rng = np.random.default_rng(4)
    x = _rand_c64(rng, (8, 8, 8))
    adj = np.asarray(plan.adjoint()(jnp.asarray(x)))
    inv = np.asarray(plan.inverse()(jnp.asarray(x)))
    np.testing.assert_allclose(adj, (8 ** 3) * inv, rtol=1e-4, atol=1e-3)


def test_derived_plan_accounting_uses_mirrored_namespace():
    """inverse()/adjoint() rename stage dims — comm_stats/flop_count work."""
    g = ProcGrid.create_abstract([4])
    b = Domain((0,), (3,))
    dom = Domain((0, 0, 0), (15, 15, 15))
    plan = fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g)
    inv = plan.inverse()
    assert inv.flop_count() == plan.flop_count()
    fwd_bytes = sum(s["bytes_per_device"] for s in plan.comm_stats())
    inv_bytes = sum(s["bytes_per_device"] for s in inv.comm_stats())
    assert inv_bytes == fwd_bytes
    assert "a2a" in inv.describe()


def test_planewave_derived_forward_accounting(g1):
    from repro.core import make_planewave_pair
    g = ProcGrid.create_abstract([4])
    sph = SphereDomain.from_diameter(16)
    inv, fwd = make_planewave_pair(g, 32, sph, 4)
    assert fwd.flop_count() == inv.flop_count()
    assert sum(s["bytes_per_device"] for s in fwd.comm_stats()) == \
        sum(s["bytes_per_device"] for s in inv.comm_stats())


def test_planewave_adjoint_inner_product_identity(g1):
    """⟨F x, y⟩ == ⟨x, F† y⟩ for both members of a plane-wave pair."""
    from repro.core import make_planewave_pair
    sph = SphereDomain.from_diameter(8)
    inv, fwd = make_planewave_pair(g1, 16, sph, 2)
    before = FftPlan.searches
    adj_inv = inv.adjoint()
    adj_fwd = fwd.adjoint()
    assert FftPlan.searches == before, "adjoint() ran a schedule search"
    rng = np.random.default_rng(10)
    x = jnp.asarray(_rand_c64(rng, (2, 8, 8, 8)))      # sphere cube side
    y = jnp.asarray(_rand_c64(rng, (2, 16, 16, 16)))   # real-space side
    lhs = np.vdot(np.asarray(inv(x)), np.asarray(y))
    rhs = np.vdot(np.asarray(x), np.asarray(adj_inv(y)))
    assert abs(lhs - rhs) / abs(lhs) < 1e-4
    lhs = np.vdot(np.asarray(fwd(y)), np.asarray(x))
    rhs = np.vdot(np.asarray(y), np.asarray(adj_fwd(x)))
    assert abs(lhs - rhs) / abs(lhs) < 1e-4


def test_plan_cache_multi_sphere_kpoints(g1):
    """k-points with distinct spheres build distinct plans; a repeated
    k-point is a cache hit — the repro.dft multi-sphere traffic pattern."""
    cache = PlanCache()
    b = Domain((0,), (1,))
    kpts = [(0.0, 0.0, 0.0), (0.5, 0.5, 0.5), (0.0, 0.0, 0.0)]
    plans = []
    for kp in kpts:
        sph = SphereDomain(radius=4.0,
                           center=tuple(3.5 + k for k in kp),
                           lower=(0, 0, 0), upper=(7, 7, 7))
        plans.append(fftb.plan_for(
            "b x{0} y z -> b X Y Z{0}", domains=(b, sph), grid=g1,
            sizes=(16, 16, 16), inverse=True, cache=cache))
    assert plans[0] is not plans[1]        # distinct spheres, distinct plans
    assert plans[2] is plans[0]            # repeated k-point hits the cache
    assert cache.stats["misses"] == 2
    assert cache.stats["hits"] == 1
    assert plans[0].sphere.npacked != plans[1].sphere.npacked


def test_build_rejects_sizes_conflicting_with_out_domains(g1):
    dom = Domain((0, 0, 0), (7, 7, 7))
    with pytest.raises(ValueError, match="extent"):
        fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1,
             out_domains=dom, sizes=(32, 32, 32))


def test_planewave_derived_forward_no_second_search(g1):
    sph = SphereDomain.from_diameter(8)
    before = FftPlan.searches
    from repro.core import make_planewave_pair
    inv, fwd = make_planewave_pair(g1, 16, sph, 2)
    assert FftPlan.searches == before + 1, \
        "a planewave pair should cost exactly one schedule search"
    rng = np.random.default_rng(6)
    packed = _rand_c64(rng, (2, sph.npacked))
    cube = inv.unpack(jnp.asarray(packed))
    rt = fwd(inv(cube))
    got = np.asarray(inv.pack(inv.mask_cube(rt)))
    np.testing.assert_allclose(got, packed, rtol=1e-3, atol=2e-5)


# ------------------------------------------------------------- ExecPolicy
def test_policy_replaces_mode_strings(g1):
    """Policies are the only call-site switch now: the legacy ``mode=``
    keyword was removed with the positional fftb signature, and legacy
    strings convert only at config boundaries via ExecPolicy.from_mode."""
    dom = Domain((0, 0, 0), (15, 15, 15))
    plan = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1,
                policy=ExecPolicy(mode="lazy"))
    rng = np.random.default_rng(7)
    x = _rand_c64(rng, (16, 16, 16))
    ref = np.fft.fftn(x)
    # default policy (lazy) and per-call override agree
    np.testing.assert_allclose(np.asarray(plan(jnp.asarray(x))), ref,
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(plan(jnp.asarray(x),
                        policy=ExecPolicy.from_mode("eager"))),
        ref, rtol=1e-4, atol=1e-3)
    with pytest.raises(TypeError):
        plan(jnp.asarray(x), mode="eager")          # shim is gone


def test_policy_legacy_mode_mapping():
    assert ExecPolicy.from_mode("lazy_bf16") == \
        ExecPolicy(mode="lazy", compute_dtype="bfloat16")
    assert ExecPolicy.from_mode("lazy_bf16").legacy_mode == "lazy_bf16"
    assert ExecPolicy().legacy_mode == "eager"
    with pytest.raises(ValueError):
        ExecPolicy.from_mode("warp_speed")
    with pytest.raises(ValueError):
        ExecPolicy(mode="lazy_bf16")        # legacy strings only via from_mode


def test_policy_check_shapes_gate(g1):
    dom = Domain((0, 0, 0), (7, 7, 7))
    plan = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1)
    bad = jnp.ones((4, 4, 4), jnp.complex64)
    with pytest.raises(ValueError):
        plan(bad)
    # unchecked call fails later (or not at all) — but not in the shape gate
    unchecked = ExecPolicy(check_shapes=False)
    try:
        plan(bad, policy=unchecked)
    except ValueError as e:                           # pragma: no cover
        assert "input shape" not in str(e)


def test_tune_pins_fastest_policy(g1):
    dom = Domain((0, 0, 0), (15, 15, 15))
    plan = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1)
    rng = np.random.default_rng(8)
    x = jnp.asarray(_rand_c64(rng, (16, 16, 16)))
    best = plan.tune(x, warmup=1, iters=1)
    assert isinstance(best, ExecPolicy)
    assert plan.policy == best          # pinned as the new default
    ref = np.fft.fftn(np.asarray(x))
    rel = np.abs(np.asarray(plan(x)) - ref).max() / np.abs(ref).max()
    assert rel < 3e-2, rel              # winner may be the bf16 executor


def test_tune_syncs_memoized_mirror(g1):
    """tune() re-pins the policy on already-derived mirrors too."""
    dom = Domain((0, 0, 0), (15, 15, 15))
    plan = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1)
    inv = plan.inverse()               # derived before tuning
    rng = np.random.default_rng(11)
    x = jnp.asarray(_rand_c64(rng, (16, 16, 16)))
    best = plan.tune(x, warmup=1, iters=1)
    assert plan.inverse() is inv       # still the memoized object
    assert inv.policy == best          # ... with the tuned policy


def test_tune_resyncs_planewave_mirrors_on_2d_grid(g1):
    """tune() on a 2D-grid plane-wave plan re-pins the tuned policy on the
    already-derived inverse *and* adjoint mirrors — and mirrors derived
    after tuning are born with it.  A (1, 1) batch×fft grid runs the 2D
    layout/spec path on a single device."""
    from repro.core import make_planewave_pair
    g2 = ProcGrid.create([1, 1], ["tb", "tf"])
    sph = SphereDomain.from_diameter(8)
    inv, fwd = make_planewave_pair(g2, 16, sph, 2, batch_axes=(0,),
                                   fft_axes=(1,))
    assert inv.tin.layout == {"b": (0,), "x": (1,)}
    adj = inv.adjoint()                # derived before tuning
    rng = np.random.default_rng(12)
    cube = jnp.asarray(_rand_c64(rng, (2, 8, 8, 8)))
    best = inv.tune(cube, warmup=1, iters=1)
    assert inv.inverse() is fwd and inv.adjoint() is adj
    assert fwd.policy == best          # memoized mirror re-synced
    assert adj.policy == best
    assert fwd.adjoint().policy == best   # derived after tune: born tuned
    # the tuned pair still round-trips on the sphere
    packed = jnp.asarray(_rand_c64(rng, (2, sph.npacked)))
    rt = inv.pack(inv.mask_cube(fwd(inv(inv.unpack(packed)))))
    np.testing.assert_allclose(np.asarray(rt), np.asarray(packed),
                               rtol=1e-2, atol=2e-2)


def test_tune_resyncs_mirrors_on_2x2_grid_4dev(dist):
    """Satellite acceptance: tune() on a real 2×2 (batch×fft) grid —
    derived inverse/adjoint mirrors pick up the tuned schedule, and the
    pair still matches the numpy reference under the tuned policy."""
    script = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import ProcGrid, SphereDomain, make_planewave_pair
assert jax.device_count() == 4
g = ProcGrid.create([2, 2], ["tb", "tf"])
sph = SphereDomain.from_diameter(16)
inv, fwd = make_planewave_pair(g, 32, sph, 4, batch_axes=(0,),
                               fft_axes=(1,))
adj = inv.adjoint()
rng = np.random.default_rng(0)
packed = (rng.standard_normal((4, sph.npacked))
          + 1j*rng.standard_normal((4, sph.npacked))).astype(np.complex64)
cube = inv.unpack(jnp.asarray(packed))
best = inv.tune(cube, warmup=1, iters=1)
assert inv.policy == best and fwd.policy == best and adj.policy == best
assert inv.inverse() is fwd and inv.adjoint() is adj
assert fwd.inverse() is inv and inv.policy == best
y = np.asarray(inv(cube))              # executes under the tuned policy
full = np.zeros((4, 32, 32, 32), np.complex64)
full[:, :16, :16, :16] = np.asarray(cube)
ref = np.fft.ifftn(full, axes=(1, 2, 3))
rel = np.abs(y - ref).max() / np.abs(ref).max()
assert rel < 3e-2, rel                 # winner may be the bf16 executor
print("OK", best.mode, best.compute_dtype)
"""
    assert "OK" in dist(script, n_devices=4)


# -------------------------------------------------------------- PlanCache
def test_plan_cache_hit_and_miss(g1):
    cache = PlanCache(maxsize=8)
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (7, 7, 7))
    p1 = fftb.plan_for("b x{0} y z -> b X Y Z{0}", domains=(b, dom),
                       grid=g1, cache=cache)
    assert cache.stats["misses"] == 1 and cache.stats["hits"] == 0
    p2 = fftb.plan_for("b x{0} y z -> b X Y Z{0}", domains=(b, dom),
                       grid=g1, cache=cache)
    assert p2 is p1
    assert cache.stats["hits"] == 1
    # different key → miss
    fftb.plan_for("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g1,
                  inverse=True, cache=cache)
    assert cache.stats["misses"] == 2


def test_repeated_apply_is_cache_hit_no_replanning(g1):
    """Acceptance: a repeated fftb.apply call never re-runs the planner."""
    cache = global_plan_cache()
    cache.clear()
    b = Domain((0,), (1,))
    dom = Domain((0, 0, 0), (7, 7, 7))
    rng = np.random.default_rng(9)
    x = jnp.asarray(_rand_c64(rng, (2, 8, 8, 8)))
    fftb.apply("b x{0} y z -> b X Y Z{0}", x, domains=(b, dom), grid=g1)
    searches = FftPlan.searches
    y = fftb.apply("b x{0} y z -> b X Y Z{0}", x, domains=(b, dom), grid=g1)
    assert FftPlan.searches == searches, "second apply re-planned"
    assert cache.stats["hits"] == 1
    np.testing.assert_allclose(np.asarray(y),
                               np.fft.fftn(np.asarray(x), axes=(1, 2, 3)),
                               rtol=2e-4, atol=2e-4)


def test_plan_cache_key_separates_policy_and_sphere(g1):
    cache = PlanCache()
    dom = Domain((0, 0, 0), (7, 7, 7))
    lazy = ExecPolicy(mode="lazy")
    a = fftb.plan_for("x{0} y z -> X Y Z{0}", domains=dom, grid=g1,
                      cache=cache)
    c = fftb.plan_for("x{0} y z -> X Y Z{0}", domains=dom, grid=g1,
                      policy=lazy, cache=cache)
    assert a is not c and c.policy == lazy
    # sphere of equal bounding box but different radius must not collide
    s1 = SphereDomain.from_diameter(8)
    s2 = SphereDomain(radius=3.0, lower=(0, 0, 0), upper=(7, 7, 7),
                      center=(3.5, 3.5, 3.5))
    b = Domain((0,), (1,))
    pw1 = fftb.plan_for("b x y z -> b X Y Z", domains=(b, s1), grid=g1,
                        sizes=(16, 16, 16), inverse=True, cache=cache)
    pw2 = fftb.plan_for("b x y z -> b X Y Z", domains=(b, s2), grid=g1,
                        sizes=(16, 16, 16), inverse=True, cache=cache)
    assert pw1 is not pw2
    assert pw1.sphere.npacked != pw2.sphere.npacked


def test_plan_cache_lru_eviction(g1):
    cache = PlanCache(maxsize=2)
    doms = [Domain((0, 0, 0), (n - 1, n - 1, n - 1)) for n in (4, 8, 16)]

    def build(d):
        return fftb.plan_for("x{0} y z -> X Y Z{0}", domains=d, grid=g1,
                             cache=cache)

    p0 = build(doms[0])
    build(doms[1])
    build(doms[0])                        # refresh dom0 → dom1 becomes LRU
    build(doms[2])                        # evicts dom1
    assert len(cache) == 2
    assert cache.stats["evictions"] == 1
    assert build(doms[0]) is p0           # still cached
    misses = cache.stats["misses"]
    build(doms[1])                        # was evicted → rebuild
    assert cache.stats["misses"] == misses + 1


def test_estimated_bytes_sphere_tables_dominate(g1):
    """Plane-wave plans charge their pack/mask tables; bigger sphere,
    bigger estimate — the quantity byte-weighted eviction runs on."""
    from repro.core import make_planewave_pair
    small, _ = make_planewave_pair(g1, 16, SphereDomain.from_diameter(8), 2)
    large, _ = make_planewave_pair(g1, 32,
                                   SphereDomain.from_diameter(16), 2)
    assert small.estimated_bytes() > small.plan.estimated_bytes()
    assert large.estimated_bytes() > 2 * small.estimated_bytes()
    tables = int(small._pack_idx.nbytes) + int(small._mask.nbytes)
    assert small.estimated_bytes() >= tables


def test_plan_cache_build_race_keeps_first_insert(g1):
    """Two threads racing on one cold key: the first inserted plan wins,
    the loser's duplicate is discarded (callers may already hold the
    winner) and the loser counts as a hit, not a second miss."""
    import threading
    cache = PlanCache()
    barrier = threading.Barrier(2)
    built, results = [], {}

    def builder():
        barrier.wait(timeout=10)          # both threads past the lookup
        obj = object()
        built.append(obj)
        return obj

    def worker(name):
        results[name] = cache.get_or_build("k", builder)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert len(built) == 2                # both really built…
    assert results[0] is results[1]       # …but everyone got the winner
    assert len(cache) == 1
    assert cache.stats["misses"] == 1     # the loser is not a miss
    assert cache.stats["hits"] == 1
    # and the cached entry stays the winner afterwards
    assert cache.get_or_build("k", lambda: object()) is results[0]


def test_plan_cache_shared_dft_tables_counted_once(g1):
    """Byte-accurate accounting: two plans sharing dft_matrix_device
    tables (same (n_out, n_in, inverse) keys) must report less than 2×
    one plan's bytes — the tables are one device allocation process-wide."""
    cache = PlanCache()
    b = Domain((0,), (1,))

    def build(center):
        sph = SphereDomain(radius=4.0, center=center, lower=(0, 0, 0),
                           upper=(7, 7, 7))
        return fftb.plan_for("b x{0} y z -> b X Y Z{0}", domains=(b, sph),
                             grid=g1, sizes=(16, 16, 16), inverse=True,
                             cache=cache)

    p1 = build((3.5, 3.5, 3.5))
    one = cache.resident_bytes
    assert one == p1.estimated_bytes()
    p2 = build((3.9, 3.9, 3.9))          # distinct sphere, same DFT tables
    assert p2 is not p1
    assert p1.shared_table_bytes() == p2.shared_table_bytes()
    two = cache.resident_bytes
    assert two < p1.estimated_bytes() + p2.estimated_bytes()
    # exactly: the second plan adds only its private (pack/mask) bytes
    assert two == one + p2.private_bytes()
    # eviction releases the tables only when the last referent leaves
    cache.clear()
    assert cache.resident_bytes == 0


def test_estimated_bytes_dedups_identical_stages(g1):
    """A staged-padding plan runs the same rectangular DFT matrix in all
    three stages — estimated_bytes charges that table once, not thrice."""
    from repro.core import make_planewave_pair
    inv, _ = make_planewave_pair(g1, 16, SphereDomain.from_diameter(8), 2)
    tables = inv.shared_table_bytes()
    assert tables == {(16, 8, True): 3 * 4 * 8 * 16}   # one key, 3 stages
    assert inv.estimated_bytes() == inv.private_bytes() + 3 * 4 * 8 * 16


def test_plan_cache_byte_weighted_eviction(g1):
    """Eviction triggers on resident bytes, not entry count: two sphere
    plans exceed the byte budget long before the 64-entry ceiling."""
    from repro.core import make_planewave_pair
    probe, _ = make_planewave_pair(g1, 16, SphereDomain.from_diameter(8), 2)
    budget = probe.estimated_bytes() + probe.estimated_bytes() // 2
    cache = PlanCache(maxsize=64, max_bytes=budget)
    b = Domain((0,), (1,))

    def build(center):
        sph = SphereDomain(radius=4.0, center=center, lower=(0, 0, 0),
                           upper=(7, 7, 7))
        return fftb.plan_for("b x{0} y z -> b X Y Z{0}", domains=(b, sph),
                             grid=g1, sizes=(16, 16, 16), inverse=True,
                             cache=cache)

    build((3.5, 3.5, 3.5))
    assert cache.stats["evictions"] == 0
    assert 0 < cache.resident_bytes <= budget
    build((4.0, 4.0, 4.0))               # second sphere breaks the budget
    assert cache.stats["evictions"] == 1
    assert len(cache) == 1               # far below maxsize=64
    assert cache.resident_bytes <= budget
    # a single entry bigger than the whole budget is still kept
    tiny = PlanCache(maxsize=4, max_bytes=1)
    tiny.get_or_build("k", lambda: probe)
    assert len(tiny) == 1
    assert tiny.resident_bytes == probe.estimated_bytes()
    stats = tiny.stats
    assert stats["resident_bytes"] == tiny.resident_bytes
    assert stats["max_bytes"] == 1
    tiny.clear()
    assert tiny.resident_bytes == 0
