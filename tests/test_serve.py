"""Serving engine: continuous batching, greedy decode correctness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.model_zoo import build
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    bundle = build(cfg)
    params = bundle.init(KEY)
    return cfg, bundle, params


def _greedy_ref(cfg, bundle, params, prompt, n_new):
    """Reference: repeated full forward + argmax (no cache)."""
    toks = list(prompt)
    from repro.models.transformer import logits_fn
    for _ in range(n_new):
        h = bundle.forward(params,
                           {"tokens": jnp.asarray([toks], jnp.int32)})
        lg = logits_fn(params, h[:, -1:], cfg)
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_uncached_greedy(tiny):
    cfg, bundle, params = tiny
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 6, dtype=np.int32)
    eng = ServeEngine(bundle, slots=1, capacity=64)
    eng.load(params)
    req = Request(rid=0, prompt=prompt, max_new=5)
    eng.submit(req)
    eng.run_until_done()
    ref = _greedy_ref(cfg, bundle, params, prompt.tolist(), 5)
    assert req.out[:5] == ref


def test_continuous_batching_more_requests_than_slots(tiny):
    cfg, bundle, params = tiny
    rng = np.random.default_rng(1)
    eng = ServeEngine(bundle, slots=2, capacity=64)
    eng.load(params)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4,
                                               dtype=np.int32), max_new=4)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    # batching must not change results vs serving each alone
    solo = ServeEngine(bundle, slots=1, capacity=64)
    solo.load(params)
    r0 = Request(rid=99, prompt=reqs[0].prompt, max_new=4)
    solo.submit(r0)
    solo.run_until_done()
    assert r0.out == reqs[0].out


def test_cache_dtype_respected_by_prefill_splice(tiny):
    """The per-slot prefill cache must use the engine's cache_dtype: with
    a bf16 engine nothing in the KV cache may round-trip through f32
    (splice's astype must be an identity cast)."""
    import dataclasses
    cfg, bundle, params = tiny
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 5, dtype=np.int32)
    seen = []

    def spy(batch, capacity, dtype):
        seen.append((batch, dtype))
        return bundle.init_cache(batch, capacity, dtype)

    spied = dataclasses.replace(bundle, init_cache=spy)
    eng = ServeEngine(spied, slots=1, capacity=64,
                      cache_dtype=jnp.bfloat16)
    assert eng.cache_dtype == jnp.bfloat16
    eng.load(params)
    eng.submit(Request(rid=0, prompt=prompt, max_new=2))
    eng.run_until_done()
    # both the batched cache and every per-slot prefill cache: bf16
    assert len(seen) >= 2
    assert all(dt == jnp.bfloat16 for _, dt in seen)
    assert all(leaf.dtype == jnp.bfloat16
               for leaf in jax.tree.leaves(eng.cache))


def test_queue_is_deque_and_mask_tracks_active(tiny):
    """Admission queue pops from the left in O(1); the per-step lengths
    increment comes from the maintained active-slot mask."""
    from collections import deque
    cfg, bundle, params = tiny
    rng = np.random.default_rng(4)
    eng = ServeEngine(bundle, slots=2, capacity=64)
    eng.load(params)
    assert isinstance(eng.queue, deque)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4,
                                               dtype=np.int32), max_new=3)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    # two admitted (FIFO), one still queued; mask mirrors active slots
    assert [r.rid for r in eng.queue] == [2]
    assert sorted(eng._active_mask.tolist()) == [1, 1]
    assert set(np.flatnonzero(eng._active_mask)) == set(eng.active)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert eng._active_mask.tolist() == [0, 0]
    # lengths advanced once per active step: prompt + generated - 1
    assert np.asarray(eng.lengths).tolist() == [4 + 3 - 1, 4 + 3 - 1]


def test_slot_reuse(tiny):
    cfg, bundle, params = tiny
    rng = np.random.default_rng(2)
    eng = ServeEngine(bundle, slots=1, capacity=64)
    eng.load(params)
    a = Request(rid=0, prompt=rng.integers(0, cfg.vocab, 4,
                                           dtype=np.int32), max_new=3)
    b = Request(rid=1, prompt=rng.integers(0, cfg.vocab, 4,
                                           dtype=np.int32), max_new=3)
    eng.submit(a)
    eng.submit(b)
    eng.run_until_done()
    assert a.done and b.done
    assert eng.free == [0]
