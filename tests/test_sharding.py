"""Sharding rules: param specs, divisibility fallback, FSDP resolution,
batch-axis logic, and an end-to-end distributed train step (subprocess)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.core.compat import abstract_mesh as _mesh
from repro.models.model_zoo import build
from repro.sharding import rules

KEY = jax.random.PRNGKey(0)


SINGLE = _mesh((16, 16), ("data", "model"))
MULTI = _mesh((2, 16, 16), ("pod", "data", "model"))


@pytest.fixture(scope="module")
def llama_shapes():
    cfg = get_config("tinyllama-1.1b")
    bundle = build(cfg)
    return jax.eval_shape(bundle.init, KEY)


def test_param_specs_tp_fsdp(llama_shapes):
    specs = rules.param_specs(llama_shapes, SINGLE)
    assert specs["embed"] == P("model", "data")
    assert specs["layers"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["wo"] == P(None, "model", "data")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", "data")
    assert specs["layers"]["ln1"] == P()


def test_fsdp_spans_pods_on_multipod(llama_shapes):
    specs = rules.param_specs(llama_shapes, MULTI)
    assert specs["layers"]["wq"] == P(None, ("pod", "data"), "model")
    assert specs["embed"] == P("model", ("pod", "data"))


def test_indivisible_vocab_replicated():
    cfg = get_config("granite-3-2b")        # vocab 49155: not /16
    bundle = build(cfg)
    shapes = jax.eval_shape(bundle.init, KEY)
    specs = rules.param_specs(shapes, SINGLE)
    assert specs["embed"] == P(None, "data")   # vocab dim dropped


def test_moe_expert_parallel():
    cfg = get_config("dbrx-132b")
    bundle = build(cfg)
    shapes = jax.eval_shape(bundle.init, KEY)
    specs = rules.param_specs(shapes, SINGLE)
    assert specs["layers"]["moe"]["w_up"] == P(None, "model", "data", None)
    assert specs["layers"]["moe"]["w_down"] == P(None, "model", None,
                                                 "data")


def test_batch_axis_divisibility():
    assert rules.batch_axis(SINGLE, 256) == ("data",)
    assert rules.batch_axis(MULTI, 256) == ("pod", "data")
    assert rules.batch_axis(MULTI, 1) is None       # long_500k: replicate
    assert rules.batch_axis(MULTI, 17) is None


def test_cache_specs_kv_fallback():
    cfg = get_config("tinyllama-1.1b")      # kv=4: not /16 → shard hd=64
    bundle = build(cfg)
    cache = jax.eval_shape(lambda: bundle.init_cache(128, 64, jnp.bfloat16))
    specs = rules.cache_specs(cfg, 128, SINGLE, cache)
    assert specs["k"] == P(None, "data", None, None, "model")


def test_drop_indivisible():
    s = rules.drop_indivisible(P("model", "data"), (49155, 2048), SINGLE)
    assert s == P(None, "data")
    s2 = rules.drop_indivisible(P(("pod", "data"), None), (64, 8), MULTI)
    assert s2 == P(("pod", "data"), None)


def test_distributed_train_step_runs(dist):
    """Real 8-device mesh: sharded params, 2 train steps, loss finite."""
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.compat import make_mesh
mesh = make_mesh((2,2,2), ("pod","data","model"))
from repro.configs.base import get_config
from repro.models.model_zoo import build
from repro.sharding import ctx, rules
from repro.train.train_step import make_train_step, init_opt_state
from repro.optim.adamw import AdamWConfig
cfg = get_config("tinyllama-1.1b").reduced()
bundle = build(cfg)
with ctx.use(mesh, ("pod","data")):
    params = bundle.init(jax.random.PRNGKey(0))
    params = jax.device_put(params, rules.param_shardings(params, mesh))
    opt = init_opt_state(params)
    opt = jax.device_put(opt, rules.param_shardings(opt, mesh))
    step = make_train_step(bundle, AdamWConfig(warmup_steps=0), mesh,
                           microbatches=2, donate=False)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    l0 = None
    for i in range(3):
        params, opt, met = step(params, opt, batch)
        if l0 is None: l0 = float(met["loss"])
    l1 = float(met["loss"])
    assert np.isfinite(l1)
    assert l1 < l0, (l0, l1)     # memorizing one batch must reduce loss
print("OK", l0, "->", l1)
"""
    assert "OK" in dist(script)


def test_grad_compression_train_step_runs(dist):
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.compat import make_mesh
mesh = make_mesh((2,), ("data",))
from repro.configs.base import get_config
from repro.models.model_zoo import build
from repro.sharding import ctx
from repro.train.train_step import make_train_step, init_opt_state
from repro.optim.adamw import AdamWConfig
cfg = get_config("tinyllama-1.1b").reduced()
bundle = build(cfg)
with ctx.use(mesh, ("data",)):
    params = bundle.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params, compress=True)
    step = make_train_step(bundle, AdamWConfig(warmup_steps=0), mesh,
                           compress=True, donate=False)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    losses = []
    for i in range(4):
        params, opt, met = step(params, opt, batch)
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0]
print("OK", losses[0], "->", losses[-1])
"""
    assert "OK" in dist(script, n_devices=2)
