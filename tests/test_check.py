"""Preflight diagnostics: golden findings for known-bad configurations.

Every check fires on a configuration a real session has hit (indivisible
extents, nk that will not stack, an over-tight cache budget), carries a
stable FFTB1xx code, and the library boundary surfaces it as a
``DiagnosticError`` whose message keeps the historical substrings.
"""
import numpy as np
import pytest

from repro.check import CODES, Diagnostic, DiagnosticError, render_diagnostics
from repro.check.diagnostics import error, raise_if_errors, warning
from repro.check.preflight import (preflight, preflight_basis,
                                   preflight_config, preflight_request,
                                   preflight_service, preflight_transform)
from repro.core import ProcGrid, fftb
from repro.core.domain import Domain
from repro.core.planewave import kpoint_sphere


def codes(diags):
    return [d.code for d in diags]


# ------------------------------------------------------------- Diagnostic
def test_diagnostic_requires_registered_code():
    with pytest.raises(ValueError, match="unregistered"):
        Diagnostic("FFTB999", "error", "nope")
    with pytest.raises(ValueError, match="severity"):
        Diagnostic("FFTB110", "fatal", "nope")


def test_diagnostic_render_and_sort():
    e = error("FFTB110", "bad width", location="n", hint="pad it")
    w = warning("FFTB114", "will not stack")
    assert e.render() == "n: FFTB110 error: bad width  [pad it]"
    # errors render before warnings regardless of input order
    text = render_diagnostics([w, e])
    assert text.splitlines()[0].startswith("n: FFTB110")


def test_diagnostic_error_is_value_error_with_codes():
    e1 = error("FFTB110", "cube width 15 must divide over the fft-axis")
    e2 = error("FFTB112", "nbands 3 not divisible by the batch-axis size 4")
    err = DiagnosticError([e1, e2])
    assert isinstance(err, ValueError)
    assert err.code == "FFTB110"
    assert "[FFTB110]" in str(err) and "[FFTB112]" in str(err)
    # the historical substring survives inside the coded message
    assert "nbands 3 not divisible" in str(err)


def test_raise_if_errors_passes_warnings_through():
    w = warning("FFTB114", "informational")
    assert raise_if_errors([w]) == [w]
    with pytest.raises(DiagnosticError):
        raise_if_errors([w, error("FFTB116", "boom")])


def test_every_emitted_code_is_registered():
    assert all(c.startswith("FFTB") for c in CODES)
    # the README/CLI table covers all three analyzer families
    assert {"FFTB101", "FFTB201", "FFTB301"} <= set(CODES)


# ------------------------------------------------------ transform preflight
def test_transform_spec_parse_error_is_fftb101():
    assert codes(preflight_transform("x y z")) == ["FFTB101"]
    assert codes(preflight_transform("x -> x")) == ["FFTB101"]


def test_transform_grid_axis_out_of_range_is_fftb102():
    g = ProcGrid.create_abstract([2])
    diags = preflight_transform("x{1} y -> X{1} Y", grid=g)
    assert codes(diags) == ["FFTB102", "FFTB102"]   # input and output side
    assert "grid has 1 axes" in diags[0].message


def test_transform_rank_mismatch_is_fftb103():
    g = ProcGrid.create_abstract([2])
    dom = kpoint_sphere(8)
    diags = preflight_transform("x y -> X Y", domains=dom, grid=g)
    assert codes(diags) == ["FFTB103"]


def test_transform_indivisible_extent_is_fftb110():
    g = ProcGrid.create_abstract([2])
    dom = Domain((0, 0, 0), (14, 14, 14))
    assert dom.extents == (15, 15, 15)
    diags = preflight_transform("x{0} y z -> X Y Z{0}", domains=dom, grid=g)
    assert codes(diags) == ["FFTB110", "FFTB110"]
    assert "divide over" in diags[0].message


def test_transform_sphere_extent_is_fftb111():
    g = ProcGrid.create_abstract([2])
    sph = kpoint_sphere(7)                       # odd bounding box
    diags = preflight_transform("x{0} y z -> X Y Z{0}", domains=sph,
                                grid=g, sizes=(16, 16, 16))
    assert "FFTB111" in codes(diags)


def test_transform_clean_spec_has_no_findings():
    g = ProcGrid.create_abstract([2])
    dom = kpoint_sphere(16)
    assert preflight_transform("x{0} y z -> X Y Z{0}", domains=dom,
                               grid=g) == []


# ---------------------------------------------------------- basis preflight
def test_basis_indivisible_extents_golden():
    # 2x2 grid: batch axis 2, fft axis 2.  n=15 and d=7 both indivisible,
    # nbands=3 does not split over the batch axis.
    diags = preflight_basis(15, diameter=7, nbands=3, grid_shape=(2, 2))
    assert codes(diags) == ["FFTB112", "FFTB110", "FFTB111"]
    by_code = {d.code: d for d in diags}
    assert "nbands 3 not divisible" in by_code["FFTB112"].message
    assert "divide over the fft-axis" in by_code["FFTB110"].message
    assert by_code["FFTB111"].hint


def test_basis_bad_axes_is_fftb113():
    diags = preflight_basis(16, grid_shape=(2, 2), batch_axes=(0, 1))
    assert codes(diags) == ["FFTB113"]
    assert "must be disjoint" in diags[0].message


def test_basis_diameter_out_of_range_is_fftb116():
    assert "FFTB116" in codes(preflight_basis(16, diameter=0))
    assert "FFTB116" in codes(preflight_basis(16, diameter=17))


def test_basis_deep_nk_does_not_stack_warns_fftb114():
    # nk=3 over batch size 2 without segments: stacked route falls back
    diags = preflight_basis(
        16, diameter=8, nbands=2, grid_shape=(2, 2),
        kpts=[(0, 0, 0), (0.1, 0, 0), (0.2, 0, 0)], deep=True)
    assert codes(diags) == ["FFTB114"]
    assert not diags[0].is_error


def test_basis_deep_segmented_stacking_is_clean():
    diags = preflight_basis(
        16, diameter=8, nbands=2, grid_shape=(2, 2),
        kpts=[(0, 0, 0), (0.1, 0, 0), (0.2, 0, 0), (0.3, 0, 0)],
        segment_padding=0.5, deep=True)
    assert diags == []


def test_basis_deep_over_budget_cache_is_fftb130():
    diags = preflight_basis(16, diameter=8, nbands=2, grid_shape=(1,),
                            cache_max_bytes=1024, deep=True)
    assert codes(diags) == ["FFTB130"]
    assert "byte budget 1024" in diags[0].message


def test_basis_bad_segment_padding_is_fftb117():
    assert "FFTB117" in codes(
        preflight_basis(16, diameter=8, segment_padding=1.5))


def test_basis_pallas_backend_small_problem_is_clean():
    diags = preflight_basis(16, diameter=8, nbands=4,
                            kpts=[(0, 0, 0), (0.5, 0.5, 0.5)],
                            grid_shape=(1,), backend="pallas")
    assert diags == []


def test_basis_unknown_backend_is_fftb118():
    diags = preflight_basis(16, diameter=8, backend="fftw")
    assert codes(diags) == ["FFTB118"]
    assert "unknown line-DFT backend 'fftw'" in diags[0].message
    # matmul and jnp requests never trip the pallas constraints
    assert preflight_basis(16, diameter=8, backend="matmul") == []
    assert preflight_basis(16, diameter=8, backend="jnp") == []


def test_basis_pallas_over_crossover_is_fftb118():
    # n=4096 exceeds MATMUL_MAX_N: the plan would silently realize 'jnp'
    diags = preflight_basis(4096, diameter=2048, grid_shape=(1,),
                            backend="pallas")
    assert codes(diags) == ["FFTB118"]
    assert "dense-DFT crossover" in diags[0].message


def test_basis_pallas_vmem_overflow_is_fftb118():
    # huge band batch on one device: the per-plane working set cannot fit
    diags = preflight_basis(128, diameter=64, nbands=64, grid_shape=(1,),
                            backend="pallas")
    assert codes(diags) == ["FFTB118"]
    assert "VMEM budget" in diags[0].message
    # sharding the batch over 4 devices shrinks the slab — but this one
    # stays over budget; a small batch fits cleanly
    assert preflight_basis(128, diameter=64, nbands=2, grid_shape=(1,),
                           backend="pallas") == []


def test_preflight_config_routes_backend_to_fftb118():
    cfg = {"n": 16, "diameter": 8, "nbands": 4, "backend": "fftw"}
    assert "FFTB118" in codes(preflight_config(cfg, grid_shape=(1,)))
    ok = dict(cfg, backend="pallas")
    assert preflight_config(ok, grid_shape=(1,)) == []


# -------------------------------------------------------- service preflight
def test_service_indivisible_cube_and_diameters():
    diags = preflight_service(15, grid_shape=(4,), diameters=(6, 20))
    assert codes(diags) == ["FFTB110", "FFTB111", "FFTB116"]


def test_service_request_golden():
    sph = kpoint_sphere(6)
    diags = preflight_request(sph, n=16, fft_procs=4, max_rows=2, nbands=5)
    assert codes(diags) == ["FFTB111", "FFTB122"]
    assert "cannot shard" in diags[0].message
    assert "split it" in diags[1].message


def test_service_request_coeff_contracts():
    sph = kpoint_sphere(8)
    bad_shape = np.zeros((2, 3), np.complex64)
    diags = preflight_request(sph, n=16, fft_procs=1, coeffs=bad_shape)
    assert "FFTB120" in codes(diags)
    bad_dtype = np.zeros((2, sph.npacked), np.float32)
    diags = preflight_request(sph, n=16, fft_procs=1, coeffs=bad_dtype)
    assert "FFTB121" in codes(diags)


# ----------------------------------------------------------- umbrella entry
def test_fftb_preflight_routes_spec_and_config():
    g = ProcGrid.create_abstract([2])
    assert codes(fftb.preflight("x y z", grid=g)) == ["FFTB101"]
    diags = fftb.preflight({"n": 15, "diameter": 7, "nbands": 3},
                           name="bad-scf", grid_shape=(2, 2))
    assert set(codes(diags)) == {"FFTB112", "FFTB110", "FFTB111"}
    assert all(d.location.startswith("bad-scf") for d in diags)
    with pytest.raises(TypeError, match="arrow-spec string or a config"):
        fftb.preflight(42)


def test_preflight_config_serve_scenario():
    cfg = {"n": 16, "d": 8, "d_small": 4, "tenants": 3, "max_rows": 8,
           "padding_budget": 0.5}
    assert preflight_config(cfg, name="serve", grid_shape=(4,)) == []
    cfg_bad = dict(cfg, d_small=3)
    diags = preflight_config(cfg_bad, name="serve", grid_shape=(4,))
    assert codes(diags) == ["FFTB111"]


def test_baseline_scenarios_self_audit_clean():
    """The shipped benchmark scenarios must pass their own preflight."""
    import json
    import pathlib

    from repro.check.preflight import preflight_scenario
    path = pathlib.Path(__file__).parent.parent / "benchmarks" / \
        "baseline.json"
    records = json.loads(path.read_text())["scenarios"]
    for name, record in records.items():
        diags = preflight_scenario(name, record)
        assert not any(d.is_error for d in diags), \
            f"{name}: {render_diagnostics(diags)}"


# ------------------------------------------------- library boundary raising
def test_plan_for_raises_coded_diagnostic():
    from repro.core.domain import Domain
    g = ProcGrid.create_abstract([2])
    dom = Domain((0, 0, 0), (14, 14, 14))
    with pytest.raises(DiagnosticError) as exc:
        fftb.plan_for("x{0} y z -> X Y Z{0}", domains=dom, grid=g)
    assert exc.value.code == "FFTB110"
    # and it is still a ValueError for legacy handlers
    with pytest.raises(ValueError, match="divide over"):
        fftb.plan_for("x{0} y z -> X Y Z{0}", domains=dom, grid=g)


def test_basis_raises_coded_diagnostic():
    from repro.dft import PlaneWaveBasis
    g2 = ProcGrid.create_abstract([2, 2])
    with pytest.raises(DiagnosticError) as exc:
        PlaneWaveBasis(16, diameter=8, nbands=3, grid=g2)
    assert exc.value.code == "FFTB112"
    assert "nbands 3 not divisible" in str(exc.value)
