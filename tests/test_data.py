"""Data pipeline: determinism, sharding, restart-safety, memmap source."""
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Pipeline


def test_labels_shift():
    p = Pipeline(DataConfig(vocab=50, seq=8, global_batch=2))
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_steps_differ():
    p = Pipeline(DataConfig(vocab=50, seq=8, global_batch=2))
    assert not np.array_equal(p.batch_at(0)["tokens"],
                              p.batch_at(1)["tokens"])


def test_seeds_differ():
    a = Pipeline(DataConfig(vocab=50, seq=8, global_batch=2, seed=0))
    b = Pipeline(DataConfig(vocab=50, seq=8, global_batch=2, seed=1))
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              b.batch_at(0)["tokens"])


def test_restart_mid_epoch_identical():
    """No iterator state: recreating the pipeline reproduces any step."""
    cfg = DataConfig(vocab=1000, seq=16, global_batch=4)
    p1 = Pipeline(cfg)
    seq = [p1.batch_at(s)["tokens"] for s in range(5)]
    p2 = Pipeline(cfg)          # "restarted" process
    np.testing.assert_array_equal(p2.batch_at(3)["tokens"], seq[3])


def test_shards_partition_batch():
    cfg = DataConfig(vocab=1000, seq=8, global_batch=8)
    full = Pipeline(cfg).batch_at(7)["tokens"]
    parts = [Pipeline(cfg, s, 4).batch_at(7)["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_backup_worker_reassignment():
    cfg = DataConfig(vocab=1000, seq=8, global_batch=8)
    healthy = Pipeline(cfg, 0, 4)
    dead_batch = Pipeline(cfg, 2, 4).batch_at(11)
    recomputed = healthy.reassign(2, 11)
    np.testing.assert_array_equal(recomputed["tokens"],
                                  dead_batch["tokens"])


def test_memmap_source(tmp_path):
    data = np.arange(10000, dtype=np.int32) % 97
    f = tmp_path / "tokens.bin"
    data.tofile(f)
    cfg = DataConfig(vocab=97, seq=16, global_batch=4, source="memmap",
                     path=str(f))
    p = Pipeline(cfg)
    b = p.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    assert (b["tokens"] < 97).all()
    b2 = Pipeline(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


def test_indivisible_shards_rejected():
    with pytest.raises(ValueError):
        Pipeline(DataConfig(vocab=10, seq=4, global_batch=4), 0, 3)
