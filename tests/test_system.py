"""End-to-end behaviour tests for the paper's system (FFTB inside the
training/serving runtime) — the integration surface a deployment hits."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import ProcGrid, SphereDomain, make_planewave_pair


def test_planewave_dft_energy_minimization():
    """Mini plane-wave DFT solve (the paper's target application):
    all-band steepest descent on a quadratic Hamiltonian must
    monotonically reduce the Rayleigh quotient energy."""
    n, nb = 16, 4
    g = ProcGrid.create([1])
    sph = SphereDomain.from_diameter(n // 2)
    inv, fwd = make_planewave_pair(g, n, sph, nb)
    rng = np.random.default_rng(0)
    c = (rng.standard_normal((nb, sph.npacked))
         + 1j * rng.standard_normal((nb, sph.npacked))).astype(np.complex64)
    c /= np.linalg.norm(c, axis=1, keepdims=True)

    # kinetic |g|²/2 on sphere points + local potential in real space
    idx = np.argwhere(sph.mask())
    g2 = ((idx - np.asarray(sph.center)) ** 2).sum(1).astype(np.float32)
    kin = jnp.asarray(0.5 * g2)
    xs = np.stack(np.meshgrid(*[np.arange(n)] * 3, indexing="ij"), -1)
    vloc = jnp.asarray(
        -2.0 * np.exp(-((xs - n / 2) ** 2).sum(-1) / 8.0).astype(np.float32))

    def h_apply(cc):
        psi = inv(inv.unpack(cc))                   # sphere → real space
        vpsi = psi * vloc
        hv = fwd(vpsi)                              # back to sphere cube
        return kin * cc + inv.pack(hv)

    def energy(cc):
        hc = h_apply(cc)
        num = jnp.sum(jnp.conj(cc) * hc, axis=1).real
        den = jnp.sum(jnp.abs(cc) ** 2, axis=1)
        return (num / den).sum()

    cc = jnp.asarray(c)
    es = [float(energy(cc))]
    for _ in range(12):
        hc = h_apply(cc)
        lam = jnp.sum(jnp.conj(cc) * hc, axis=1, keepdims=True).real
        grad = hc - lam * cc
        cc = cc - 0.1 * grad
        cc = cc / jnp.linalg.norm(cc, axis=1, keepdims=True)
        es.append(float(energy(cc)))
    assert es[-1] < es[0] - 0.1, es
    assert all(b <= a + 1e-3 for a, b in zip(es, es[1:])), es


def test_fftb_feature_matrix():
    """Executable Table 1: the capabilities FFTB claims vs other libs."""
    g1 = ProcGrid.create([1])
    g2 = ProcGrid.create([1, 1])
    g3 = ProcGrid.create([1, 1, 1])
    assert (g1.ndim, g2.ndim, g3.ndim) == (1, 2, 3)      # 1D/2D/3D grids
    sph = SphereDomain.from_diameter(8)
    assert sph.npacked > 0                                # sphere inputs
    inv, fwd = make_planewave_pair(g1, 16, sph, 3)        # batched + CtoC
    x = jnp.ones((3, 8, 8, 8), jnp.complex64)
    assert inv(x).dtype == jnp.complex64
    assert inv(x).shape == (3, 16, 16, 16)


def test_train_then_serve_same_params():
    """Train a few steps, then serve with the trained params — the full
    lifecycle a deployment runs (train → checkpoint → serve)."""
    import tempfile
    from repro.data.pipeline import DataConfig
    from repro.models.model_zoo import build
    from repro.optim.adamw import AdamWConfig
    from repro.serve.engine import Request, ServeEngine
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.ckpt.checkpoint import CheckpointManager

    cfg = get_config("tinyllama-1.1b").reduced()
    bundle = build(cfg)
    with tempfile.TemporaryDirectory() as td:
        tcfg = TrainerConfig(total_steps=3, ckpt_every=100, log_every=100,
                             ckpt_dir=td)
        dcfg = DataConfig(vocab=cfg.vocab, seq=16, global_batch=2)
        tr = Trainer(bundle, AdamWConfig(warmup_steps=0), tcfg, dcfg)
        tr.run()
        cm = CheckpointManager(td)
        step, tree = cm.restore()
        assert step == 3
        eng = ServeEngine(bundle, slots=1, capacity=32)
        eng.load(tree["params"])
        req = Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                      max_new=3)
        eng.submit(req)
        eng.run_until_done()
        assert len(req.out) == 3
