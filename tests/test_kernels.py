"""Per-kernel sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.dft_matmul import dft_matmul


def _cx(rng, shape):
    return (rng.standard_normal(shape)
            + 1j * rng.standard_normal(shape)).astype(np.complex64)


@pytest.mark.parametrize("B", [1, 8, 33, 256])
@pytest.mark.parametrize("n", [8, 16, 128])
def test_dft_apply_square_shapes(B, n):
    rng = np.random.default_rng(B * 1000 + n)
    x = _cx(rng, (B, n))
    y = np.asarray(ops.dft_apply(jnp.asarray(x)))
    r = np.asarray(ref.dft_apply_ref(jnp.asarray(x)))
    np.testing.assert_allclose(y, r, rtol=2e-4, atol=2e-4 * n)


@pytest.mark.parametrize("n_in,n_out", [(8, 32), (16, 16), (32, 8),
                                        (24, 48), (128, 64)])
@pytest.mark.parametrize("inverse", [False, True])
def test_dft_apply_rectangular(n_in, n_out, inverse):
    rng = np.random.default_rng(n_in * 100 + n_out + inverse)
    x = _cx(rng, (16, n_in))
    y = np.asarray(ops.dft_apply(jnp.asarray(x), n_out, inverse=inverse))
    r = np.asarray(ref.dft_apply_ref(jnp.asarray(x), n_out,
                                     inverse=inverse))
    np.testing.assert_allclose(y, r, rtol=2e-4, atol=1e-5 * max(n_in, 1))


def test_raw_kernel_vs_complex_matmul():
    rng = np.random.default_rng(7)
    B, K, N = 64, 32, 48
    xr = rng.standard_normal((B, K)).astype(np.float32)
    xi = rng.standard_normal((B, K)).astype(np.float32)
    wr = rng.standard_normal((N, K)).astype(np.float32)
    wi = rng.standard_normal((N, K)).astype(np.float32)
    yr, yi = dft_matmul(jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(wr),
                        jnp.asarray(wi), bm=32, bn=16, interpret=True)
    rr, ri = ref.complex_matmul_ref(xr, xi, wr, wi)
    np.testing.assert_allclose(np.asarray(yr), rr, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(yi), ri, rtol=1e-4, atol=1e-3)


def test_kernel_twiddle_epilogue():
    rng = np.random.default_rng(8)
    B, K, N = 32, 16, 16
    xr, xi, wr, wi, tr, ti = [
        rng.standard_normal(s).astype(np.float32)
        for s in [(B, K), (B, K), (N, K), (N, K), (B, N), (B, N)]]
    yr, yi = dft_matmul(*map(jnp.asarray, (xr, xi, wr, wi, tr, ti)),
                        bm=16, bn=16, interpret=True)
    rr, ri = ref.complex_matmul_ref(xr, xi, wr, wi)
    err = np.abs(np.asarray(yr) - (rr * tr - ri * ti)).max()
    eri = np.abs(np.asarray(yi) - (rr * ti + ri * tr)).max()
    assert err < 1e-3 and eri < 1e-3


@pytest.mark.parametrize("n", [64, 360, 1024])
@pytest.mark.parametrize("inverse", [False, True])
def test_four_step_vs_fft(n, inverse):
    rng = np.random.default_rng(n + inverse)
    x = _cx(rng, (4, n))
    y = np.asarray(ops.four_step_dft(jnp.asarray(x), inverse=inverse))
    r = np.asarray(ref.four_step_ref(jnp.asarray(x), inverse=inverse))
    scale = np.abs(r).max()
    np.testing.assert_allclose(y, r, rtol=0, atol=3e-6 * n * max(scale, 1))


def test_four_step_rejects_prime():
    with pytest.raises(ValueError):
        ops.four_step_dft(jnp.zeros((2, 13), jnp.complex64))


def test_local_dft_backends_agree():
    from repro.core.local_fft import local_dft
    rng = np.random.default_rng(9)
    x = jnp.asarray(_cx(rng, (3, 5, 24)))
    outs = [np.asarray(local_dft(x, 2, 32, backend=b))
            for b in ("jnp", "matmul", "pallas")]
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=1e-4)
