"""Per-kernel sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.dft_matmul import dft_matmul


def _cx(rng, shape):
    return (rng.standard_normal(shape)
            + 1j * rng.standard_normal(shape)).astype(np.complex64)


@pytest.mark.parametrize("B", [1, 8, 33, 256])
@pytest.mark.parametrize("n", [8, 16, 128])
def test_dft_apply_square_shapes(B, n):
    rng = np.random.default_rng(B * 1000 + n)
    x = _cx(rng, (B, n))
    y = np.asarray(ops.dft_apply(jnp.asarray(x)))
    r = np.asarray(ref.dft_apply_ref(jnp.asarray(x)))
    np.testing.assert_allclose(y, r, rtol=2e-4, atol=2e-4 * n)


@pytest.mark.parametrize("n_in,n_out", [(8, 32), (16, 16), (32, 8),
                                        (24, 48), (128, 64)])
@pytest.mark.parametrize("inverse", [False, True])
def test_dft_apply_rectangular(n_in, n_out, inverse):
    rng = np.random.default_rng(n_in * 100 + n_out + inverse)
    x = _cx(rng, (16, n_in))
    y = np.asarray(ops.dft_apply(jnp.asarray(x), n_out, inverse=inverse))
    r = np.asarray(ref.dft_apply_ref(jnp.asarray(x), n_out,
                                     inverse=inverse))
    np.testing.assert_allclose(y, r, rtol=2e-4, atol=1e-5 * max(n_in, 1))


def test_raw_kernel_vs_complex_matmul():
    rng = np.random.default_rng(7)
    B, K, N = 64, 32, 48
    xr = rng.standard_normal((B, K)).astype(np.float32)
    xi = rng.standard_normal((B, K)).astype(np.float32)
    wr = rng.standard_normal((N, K)).astype(np.float32)
    wi = rng.standard_normal((N, K)).astype(np.float32)
    yr, yi = dft_matmul(jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(wr),
                        jnp.asarray(wi), bm=32, bn=16, interpret=True)
    rr, ri = ref.complex_matmul_ref(xr, xi, wr, wi)
    np.testing.assert_allclose(np.asarray(yr), rr, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(yi), ri, rtol=1e-4, atol=1e-3)


def test_kernel_twiddle_epilogue():
    rng = np.random.default_rng(8)
    B, K, N = 32, 16, 16
    xr, xi, wr, wi, tr, ti = [
        rng.standard_normal(s).astype(np.float32)
        for s in [(B, K), (B, K), (N, K), (N, K), (B, N), (B, N)]]
    yr, yi = dft_matmul(*map(jnp.asarray, (xr, xi, wr, wi, tr, ti)),
                        bm=16, bn=16, interpret=True)
    rr, ri = ref.complex_matmul_ref(xr, xi, wr, wi)
    err = np.abs(np.asarray(yr) - (rr * tr - ri * ti)).max()
    eri = np.abs(np.asarray(yi) - (rr * ti + ri * tr)).max()
    assert err < 1e-3 and eri < 1e-3


@pytest.mark.parametrize("n", [64, 360, 1024])
@pytest.mark.parametrize("inverse", [False, True])
def test_four_step_vs_fft(n, inverse):
    rng = np.random.default_rng(n + inverse)
    x = _cx(rng, (4, n))
    y = np.asarray(ops.four_step_dft(jnp.asarray(x), inverse=inverse))
    r = np.asarray(ref.four_step_ref(jnp.asarray(x), inverse=inverse))
    scale = np.abs(r).max()
    np.testing.assert_allclose(y, r, rtol=0, atol=3e-6 * n * max(scale, 1))


def test_four_step_rejects_prime():
    with pytest.raises(ValueError):
        ops.four_step_dft(jnp.zeros((2, 13), jnp.complex64))


def test_local_dft_backends_agree():
    from repro.core.local_fft import local_dft
    rng = np.random.default_rng(9)
    x = jnp.asarray(_cx(rng, (3, 5, 24)))
    outs = [np.asarray(local_dft(x, 2, 32, backend=b))
            for b in ("jnp", "matmul", "pallas")]
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=1e-4)


# ---------------------------------------------- fused sphere-pack kernels
def _sphere_batch(d, kpts):
    from repro.core import kpoint_sphere
    return [kpoint_sphere(d, kp) for kp in kpts]


def _composed_unpack_dft(spheres, nbands, pr, pi, wr, wi):
    """Oracle: scatter into the zero cube, then the matmul-backend GEMM."""
    ex, ey, ez = spheres[0].extents
    B = pr.shape[0]
    n = wr.shape[0]
    cr = np.zeros((B, ex * ey * ez), np.float32)
    ci = np.zeros((B, ex * ey * ez), np.float32)
    for b in range(B):
        s = spheres[b // nbands]
        idx = s.pack_indices()
        cr[b, idx] = pr[b, :s.npacked]
        ci[b, idx] = pi[b, :s.npacked]
    xr = jnp.asarray(cr.reshape(B * ex * ey, ez))
    xi = jnp.asarray(ci.reshape(B * ex * ey, ez))
    yr = xr @ jnp.asarray(wr).T - xi @ jnp.asarray(wi).T
    yi = xr @ jnp.asarray(wi).T + xi @ jnp.asarray(wr).T
    return (np.asarray(yr).reshape(B, ex, ey, n),
            np.asarray(yi).reshape(B, ex, ey, n))


def _composed_dft_pack(spheres, nbands, xr, xi, wr, wi, npm):
    """Oracle: last-stage GEMM into the cube, then the CSR gather."""
    B, ex, ey, n = xr.shape
    d = wr.shape[0]
    fr = jnp.asarray(xr.reshape(B * ex * ey, n))
    fi = jnp.asarray(xi.reshape(B * ex * ey, n))
    yr = np.asarray(fr @ jnp.asarray(wr).T - fi @ jnp.asarray(wi).T
                    ).reshape(B, ex * ey * d)
    yi = np.asarray(fr @ jnp.asarray(wi).T + fi @ jnp.asarray(wr).T
                    ).reshape(B, ex * ey * d)
    pr = np.zeros((B, npm), np.float32)
    pi = np.zeros((B, npm), np.float32)
    for b in range(B):
        s = spheres[b // nbands]
        idx = s.pack_indices()
        pr[b, :s.npacked] = yr[b, idx]
        pi[b, :s.npacked] = yi[b, idx]
    return pr, pi


@pytest.mark.parametrize("d,n,nbands,kpts", [
    (8, 16, 3, ((0, 0, 0), (0.5, 0.5, 0.5))),
    (6, 12, 2, ((0, 0, 0),)),
    (4, 8, 1, ((0.25, 0, 0.5), (0, 0, 0), (0.5, 0.5, 0))),
])
def test_unpack_dft_bitwise_vs_composed(d, n, nbands, kpts):
    from repro.core.local_fft import dft_matrix_device
    from repro.kernels import sphere_pack

    spheres = _sphere_batch(d, kpts)
    B = len(spheres) * nbands
    npm = max(s.npacked for s in spheres)
    rng = np.random.default_rng(d * 100 + n)
    # garbage beyond each row's npacked lanes: the line tables must never
    # read it (padded tails of the ragged stacked batch are untrusted)
    pr = rng.standard_normal((B, npm)).astype(np.float32)
    pi = rng.standard_normal((B, npm)).astype(np.float32)
    start, zlo, cnt, flag = sphere_pack.line_tables(spheres, nbands)
    wr, wi, _ = dft_matrix_device(n, d, True)
    yr, yi = sphere_pack.unpack_dft(
        jnp.asarray(pr), jnp.asarray(pi), jnp.asarray(start),
        jnp.asarray(zlo), jnp.asarray(cnt), jnp.asarray(flag), wr, wi,
        interpret=True)
    # the oracle reads only valid lanes — zero the tails it would scatter
    pr_v, pi_v = pr.copy(), pi.copy()
    for b in range(B):
        pr_v[b, spheres[b // nbands].npacked:] = 0.0
        pi_v[b, spheres[b // nbands].npacked:] = 0.0
    rr, ri = _composed_unpack_dft(spheres, nbands, pr_v, pi_v,
                                  np.asarray(wr), np.asarray(wi))
    assert np.abs(np.asarray(yr) - rr).max() == 0.0
    assert np.abs(np.asarray(yi) - ri).max() == 0.0


@pytest.mark.parametrize("d,n,nbands,kpts", [
    (8, 16, 3, ((0, 0, 0), (0.5, 0.5, 0.5))),
    (6, 12, 2, ((0, 0, 0),)),
])
def test_dft_pack_bitwise_and_padded_lanes_zero(d, n, nbands, kpts):
    from repro.core.local_fft import dft_matrix_device
    from repro.kernels import sphere_pack

    spheres = _sphere_batch(d, kpts)
    B = len(spheres) * nbands
    npm = max(s.npacked for s in spheres)
    rng = np.random.default_rng(d * 7 + n)
    ex, ey, _ = spheres[0].extents
    xr = rng.standard_normal((B, ex, ey, n)).astype(np.float32)
    xi = rng.standard_normal((B, ex, ey, n)).astype(np.float32)
    line, zz, valid = sphere_pack.pack_gather_tables(spheres, nbands, npm)
    g = line * d + zz
    wr, wi, _ = dft_matrix_device(d, n, False)
    pr, pi = sphere_pack.dft_pack(
        jnp.asarray(xr), jnp.asarray(xi), jnp.asarray(g),
        jnp.asarray(valid), wr, wi, interpret=True)
    rr, ri = _composed_dft_pack(spheres, nbands, xr, xi,
                                np.asarray(wr), np.asarray(wi), npm)
    assert np.abs(np.asarray(pr) - rr).max() == 0.0
    assert np.abs(np.asarray(pi) - ri).max() == 0.0
    # padded lanes are exact +0.0 whatever the slab held (the ragged
    # two-sphere case has them; a single sphere pads nothing)
    pad = valid == 0
    assert pad.any() == (len(spheres) > 1)
    assert np.all(np.asarray(pr)[pad] == 0.0)
    assert np.all(np.asarray(pi)[pad] == 0.0)


def test_unpack_dft_zero_skip_planes():
    """A plane with flag=0 writes exact zeros without reading lanes."""
    from repro.core.local_fft import dft_matrix_device
    from repro.kernels import sphere_pack

    spheres = _sphere_batch(6, ((0, 0, 0),))
    start, zlo, cnt, flag = sphere_pack.line_tables(spheres, 2)
    B, npm = 2, spheres[0].npacked
    rng = np.random.default_rng(3)
    pr = rng.standard_normal((B, npm)).astype(np.float32)
    pi = rng.standard_normal((B, npm)).astype(np.float32)
    wr, wi, _ = dft_matrix_device(12, 6, True)
    flag0 = flag.copy()
    flag0[2] = 0                      # force the skip path on plane x=2
    yr, _ = sphere_pack.unpack_dft(
        jnp.asarray(pr), jnp.asarray(pi), jnp.asarray(start),
        jnp.asarray(zlo), jnp.asarray(cnt), jnp.asarray(flag0), wr, wi,
        interpret=True)
    assert np.all(np.asarray(yr)[:, 2] == 0.0)
    assert np.any(np.asarray(yr)[:, 1] != 0.0)


def test_line_tables_round_trip():
    """(start, zlo, cnt) reconstruct pack_indices exactly, per sphere."""
    from repro.kernels import sphere_pack

    spheres = _sphere_batch(8, ((0, 0, 0), (0.5, 0.5, 0.5)))
    ex, ey, ez = spheres[0].extents
    start, zlo, cnt, flag = sphere_pack.line_tables(spheres, 1)
    for k, s in enumerate(spheres):
        flat = []
        for l in range(ex * ey):
            for j in range(cnt[k, l]):
                flat.append(l * ez + zlo[k, l] + j)
                assert start[k, l] + j == len(flat) - 1
        assert np.array_equal(np.asarray(flat), s.pack_indices())
    assert flag.shape == (ex, 1) and flag.any()


def test_realized_backend_and_flops():
    from repro.core.local_fft import (MATMUL_MAX_N, dft_flops,
                                      realized_backend)
    assert realized_backend(16, 32, "matmul") == "matmul"
    assert realized_backend(16, 32, "pallas") == "pallas"
    assert realized_backend(16, 32, "jnp") == "jnp"
    big = MATMUL_MAX_N + 1
    assert realized_backend(big, big, "matmul") == "jnp"
    assert realized_backend(16, big, "pallas") == "jnp"
    with pytest.raises(ValueError):
        realized_backend(8, 8, "fftw")
    # above the crossover, flops are priced at the realized jnp backend
    assert dft_flops(big, big, 4, "matmul") == dft_flops(big, big, 4, "jnp")
    assert dft_flops(32, 16, 4, "pallas") == 8 * 32 * 16 * 4
