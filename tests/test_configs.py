"""Assignment-exactness tests: every arch config carries the published
numbers, and the dry-run harness pieces behave (HLO parser, input specs)."""
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, all_configs, get_config

EXACT = {
    "qwen3-32b": {"n_layers": 64, "d_model": 5120, "n_heads": 64,
                  "n_kv": 8, "d_ff": 25600, "vocab": 151936,
                  "qk_norm": True, "family": "dense"},
    "tinyllama-1.1b": {"n_layers": 22, "d_model": 2048, "n_heads": 32,
                       "n_kv": 4, "d_ff": 5632, "vocab": 32000,
                       "family": "dense"},
    "nemotron-4-340b": {"n_layers": 96, "d_model": 18432, "n_heads": 96,
                        "n_kv": 8, "d_ff": 73728, "vocab": 256000,
                        "activation": "relu2", "family": "dense"},
    "granite-3-2b": {"n_layers": 40, "d_model": 2048, "n_heads": 32,
                     "n_kv": 8, "d_ff": 8192, "vocab": 49155,
                     "family": "dense"},
    "pixtral-12b": {"n_layers": 40, "d_model": 5120, "n_heads": 32,
                    "n_kv": 8, "d_ff": 14336, "vocab": 131072,
                    "family": "vlm"},
    "granite-moe-3b-a800m": {"n_layers": 32, "d_model": 1536,
                             "n_heads": 24, "n_kv": 8, "d_ff": 512,
                             "vocab": 49155, "n_experts": 40, "top_k": 8,
                             "family": "moe"},
    "dbrx-132b": {"n_layers": 40, "d_model": 6144, "n_heads": 48,
                  "n_kv": 8, "d_ff": 10752, "vocab": 100352,
                  "n_experts": 16, "top_k": 4, "family": "moe"},
    "whisper-small": {"n_layers": 12, "d_model": 768, "n_heads": 12,
                      "n_kv": 12, "d_ff": 3072, "vocab": 51865,
                      "enc_layers": 12, "enc_seq": 1500,
                      "family": "encdec"},
    "recurrentgemma-9b": {"n_layers": 38, "d_model": 4096, "n_heads": 16,
                          "n_kv": 1, "d_ff": 12288, "vocab": 256000,
                          "local_window": 2048, "family": "hybrid",
                          "block_pattern": ("rec", "rec", "attn")},
    "mamba2-370m": {"n_layers": 48, "d_model": 1024, "d_ff": 0,
                    "vocab": 50280, "ssm_state": 128, "family": "ssm"},
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_config(arch):
    cfg = get_config(arch)
    for field, want in EXACT[arch].items():
        assert getattr(cfg, field) == want, (arch, field)


def test_all_archs_registered():
    assert set(all_configs()) >= set(ARCH_IDS)


def test_shapes_exact():
    assert (SHAPES["train_4k"].seq, SHAPES["train_4k"].batch) == (4096, 256)
    assert (SHAPES["prefill_32k"].seq, SHAPES["prefill_32k"].batch) \
        == (32768, 32)
    assert (SHAPES["decode_32k"].seq, SHAPES["decode_32k"].batch) \
        == (32768, 128)
    assert (SHAPES["long_500k"].seq, SHAPES["long_500k"].batch) \
        == (524288, 1)


def test_paper_workload_config():
    from repro.configs.fftb_paper import CONFIG
    assert (CONFIG.n, CONFIG.diameter, CONFIG.nb) == (256, 128, 256)


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %a2a = f32[32,32]{1,0} all-to-all(%z), replica_groups={{0,1},{2,3}}
  %cp = (f32[16,16]{1,0}, f32[16,16]{1,0}) collective-permute-start(%w), source_target_pairs={{0,1}}
  %other = f32[9,9] add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 64 * 512 * 2 // 8
    assert out["all-to-all"] == 32 * 32 * 4
    assert out["collective-permute"] == 16 * 16 * 4     # start pair halved


def test_input_specs_shapes():
    from repro.launch.dryrun import input_specs
    b = input_specs("qwen3-32b", "train_4k")
    assert b["tokens"].shape == (256, 4096)
    b = input_specs("pixtral-12b", "train_4k")
    assert b["tokens"].shape == (256, 4096 - 1024)
    assert b["image_embeds"].shape == (256, 1024, 5120)
    b = input_specs("whisper-small", "prefill_32k")
    assert b["frames"].shape == (32, 1500, 768)
    b = input_specs("mamba2-370m", "long_500k")
    assert b["tokens"].shape == (1, 1)


def test_reduced_configs_stay_in_family():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        r = cfg.reduced()
        assert r.family == cfg.family
        assert r.d_model <= 128 and r.vocab <= 1024
