"""Multi-tenant transform service: coalescing, fairness, robustness.

The acceptance matrix: a mixed-workload trace (3 tenants, 2 sphere
shapes) served concurrently must equal per-request eager dispatch
bitwise on 1 device (in-process) and 4 devices (subprocess); coalesced
requests share one stacked dispatch (``FftPlan.executions``); realized
padding stays within the configured budget; deadlines expire as errors,
never hangs.
"""
import threading

import numpy as np
import pytest

from repro.core import (FftPlan, PlanCache, ProcGrid, global_plan_cache,
                        kpoint_sphere)
from repro.serve import (DeadlineExceeded, QueueFull, ServiceStopped,
                        TransformService)

N = 16
D = 8


@pytest.fixture()
def g1():
    return ProcGrid.create([1])


@pytest.fixture()
def svc(g1):
    global_plan_cache().clear()
    return TransformService(g1, N, padding_budget=0.5, max_rows=8,
                            warm_async=False)


def _coeffs(rng, nbands, sphere):
    return (rng.standard_normal((nbands, sphere.npacked))
            + 1j * rng.standard_normal((nbands, sphere.npacked))
            ).astype(np.complex64)


SPH_G = kpoint_sphere(D)                       # gamma point, cutoff d=8
SPH_K = kpoint_sphere(D, (0.5, 0.5, 0.5))      # k-shifted, same cutoff
SPH_S = kpoint_sphere(6)                       # smaller cutoff — other class


# --------------------------------------------------------------- coalescing
def test_mixed_trace_matches_eager_bitwise(svc):
    """3 tenants × 2 sphere shapes, concurrent submits — bitwise oracle."""
    rng = np.random.default_rng(0)
    veff = rng.standard_normal((N,) * 3).astype(np.float32)
    work = [("t0", _coeffs(rng, 2, SPH_G), SPH_G, veff),
            ("t1", _coeffs(rng, 2, SPH_K), SPH_K, None),
            ("t2", _coeffs(rng, 1, SPH_S), SPH_S, veff),
            ("t0", _coeffs(rng, 3, SPH_K), SPH_K, None),
            ("t2", _coeffs(rng, 2, SPH_S), SPH_S, None)]
    handles = [None] * len(work)

    def submit(i):
        t, c, s, v = work[i]
        handles[i] = svc.submit(t, c, s, v_eff=v)

    threads = [threading.Thread(target=submit, args=(i,))
               for i in range(len(work))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    svc.run_until_idle()
    for h, (_, c, s, v) in zip(handles, work):
        np.testing.assert_array_equal(h.result(5), svc.eager_apply(c, s, v))
    m = svc.metrics.summary()
    assert m["requests"] == 5
    assert m["coalesced_dispatches"] >= 1       # the d=8 class coalesced


def test_coalesced_requests_share_one_stacked_dispatch(svc):
    """3 compatible requests → one dispatch → exactly 2 plan executions."""
    rng = np.random.default_rng(1)
    svc.warm(SPH_G, 6)                          # plans hot before measuring
    hs = [svc.submit(f"t{i}", _coeffs(rng, 2, s), s)
          for i, s in enumerate((SPH_G, SPH_K, SPH_G))]
    before = FftPlan.executions
    assert svc.step() == 3                      # all three in one batch
    assert FftPlan.executions - before == 2     # one inverse + one forward
    for h in hs:
        assert h.done()
    m = svc.metrics.summary()
    assert m["dispatches"] == 1 and m["coalesced_dispatches"] == 1


def test_eager_baseline_two_dispatches_per_request(svc):
    """The contrast: coalesce=False serves the same 3 requests in 3
    dispatches (6 executions) — what the scheduler saves."""
    rng = np.random.default_rng(2)
    solo = TransformService(svc.grid, N, coalesce=False, warm_async=False)
    solo.warm(SPH_G, 2), solo.warm(SPH_K, 2)
    for i, s in enumerate((SPH_G, SPH_K, SPH_G)):
        solo.submit(f"t{i}", _coeffs(rng, 2, s), s)
    before = FftPlan.executions
    solo.run_until_idle()
    assert FftPlan.executions - before == 6
    assert solo.metrics.summary()["dispatches"] == 3


def test_incompatible_shapes_never_coalesce(svc):
    """Different cutoff diameters are distinct compat classes."""
    rng = np.random.default_rng(3)
    svc.submit("a", _coeffs(rng, 2, SPH_G), SPH_G)
    svc.submit("b", _coeffs(rng, 2, SPH_S), SPH_S)
    svc.run_until_idle()
    m = svc.metrics.summary()
    assert m["dispatches"] == 2 and m["coalesced_dispatches"] == 0


# ----------------------------------------------------------- padding budget
def test_padding_within_budget_and_split_when_exceeded(g1):
    """A lean sphere only joins a fat-sphere batch when the budget allows.

    SPH_S2 has the d=8 bounding box but a much smaller radius, so padding
    its rows to SPH_G's npacked_max is expensive: a tight budget must
    split the pair into two dispatches, a loose one coalesces — and the
    realized padding respects the budget either way.
    """
    sph_s2 = kpoint_sphere(D)
    sph_s2 = type(sph_s2)(radius=2.0, lower=(0, 0, 0), upper=(D - 1,) * 3,
                          center=sph_s2.center)
    rng = np.random.default_rng(4)
    for budget, want_dispatches in ((0.05, 2), (0.9, 1)):
        global_plan_cache().clear()
        svc = TransformService(g1, N, padding_budget=budget,
                               warm_async=False)
        ha = svc.submit("a", _coeffs(rng, 1, SPH_G), SPH_G)
        hb = svc.submit("b", _coeffs(rng, 1, sph_s2), sph_s2)
        svc.run_until_idle()
        assert ha.done() and hb.done()
        m = svc.metrics.summary()
        assert m["dispatches"] == want_dispatches
        assert m["padding_fraction_max"] <= budget


# ------------------------------------------------------------- robustness
def test_deadline_expires_as_error_not_hang(svc):
    rng = np.random.default_rng(5)
    h = svc.submit("t0", _coeffs(rng, 1, SPH_G), SPH_G, deadline=-0.001)
    svc.step()
    assert h.done()
    with pytest.raises(DeadlineExceeded):
        h.result(1)
    assert svc.metrics.summary()["errors"] == {"deadline": 1}


def test_deadline_spares_requests_still_in_time(svc):
    rng = np.random.default_rng(6)
    late = svc.submit("t0", _coeffs(rng, 1, SPH_G), SPH_G, deadline=-0.001)
    ok = svc.submit("t0", _coeffs(rng, 1, SPH_G), SPH_G, deadline=60.0)
    svc.run_until_idle()
    with pytest.raises(DeadlineExceeded):
        late.result(1)
    assert ok.result(1).shape == (1, SPH_G.npacked)


def test_queue_depth_backpressure(g1):
    svc = TransformService(g1, N, max_queue_per_tenant=2, warm_async=False)
    rng = np.random.default_rng(7)
    for _ in range(2):
        svc.submit("flood", _coeffs(rng, 1, SPH_G), SPH_G)
    with pytest.raises(QueueFull):
        svc.submit("flood", _coeffs(rng, 1, SPH_G), SPH_G)
    # other tenants are not throttled by one tenant's backlog
    svc.submit("calm", _coeffs(rng, 1, SPH_G), SPH_G)
    svc.run_until_idle()


def test_round_robin_fairness_across_tenants(svc):
    """A flooding tenant cannot starve another: with coalescing off, the
    dispatch order must interleave tenants, not drain the flood first."""
    rng = np.random.default_rng(8)
    svc.scheduler.max_rows = 1                  # force one request per batch
    order = []
    flood = [svc.submit("flood", _coeffs(rng, 1, SPH_G), SPH_G)
             for _ in range(4)]
    nice = svc.submit("nice", _coeffs(rng, 1, SPH_G), SPH_G)
    while len(svc.scheduler):
        svc.step()
        done = {id(h) for h in flood + [nice] if h.done()}
        order.append(("nice" if id(nice) in done else "flood", len(done)))
    # nice resolved by the second dispatch, with 3 floods still queued
    assert any(t == "nice" and k <= 2 for t, k in order)


def test_stop_fails_pending_requests(g1):
    svc = TransformService(g1, N, warm_async=False)
    rng = np.random.default_rng(9)
    h = svc.submit("t0", _coeffs(rng, 1, SPH_G), SPH_G)
    svc.stop(drain=False)
    with pytest.raises(ServiceStopped):
        h.result(1)
    with pytest.raises(ServiceStopped):
        svc.submit("t0", _coeffs(rng, 1, SPH_G), SPH_G)


def test_background_loop_with_async_admission(g1):
    """start()/stop() + warm_async: cold plans build off the loop thread,
    every request still resolves, and the plan cache saw real traffic."""
    cache = PlanCache()
    svc = TransformService(g1, N, cache=cache, warm_async=True)
    rng = np.random.default_rng(10)
    svc.start()
    hs = [svc.submit(f"t{i % 3}", _coeffs(rng, 2, s), s)
          for i, s in enumerate((SPH_G, SPH_K, SPH_G, SPH_K))]
    for h in hs:
        assert h.result(60).dtype == np.complex64
    svc.stop()
    assert cache.stats["misses"] > 0
    assert svc.metrics.summary()["requests"] == 4


# ------------------------------------------------------------ multi-device
def test_service_bitwise_on_4_devices(dist):
    """Coalesced == eager bitwise on a 4-device fft-sharded grid."""
    dist("""
import numpy as np
from repro.core import ProcGrid, kpoint_sphere
from repro.serve import TransformService

g = ProcGrid.create([4])
n, d = 16, 8
sA, sB = kpoint_sphere(d), kpoint_sphere(d, (0.5, 0.5, 0.5))
rng = np.random.default_rng(0)
def rc(nb, s):
    return (rng.standard_normal((nb, s.npacked))
            + 1j * rng.standard_normal((nb, s.npacked))).astype(np.complex64)
svc = TransformService(g, n, warm_async=False)
veff = rng.standard_normal((n,) * 3).astype(np.float32)
work = [("t0", rc(2, sA), sA, veff), ("t1", rc(2, sB), sB, None),
        ("t2", rc(1, sA), sA, None)]
hs = [svc.submit(t, c, s, v_eff=v) for t, c, s, v in work]
svc.run_until_idle()
m = svc.metrics.summary()
assert m["coalesced_dispatches"] >= 1, m
for h, (_, c, s, v) in zip(hs, work):
    out, ref = h.result(10), svc.eager_apply(c, s, v)
    assert np.array_equal(out, ref), abs(out - ref).max()
print("OK")
""", n_devices=4)
