"""Plane-wave sphere transform: CSR offsets, pack/unpack, staged padding,
ragged k-stacked batches."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (ProcGrid, SphereDomain, make_planewave_pair,
                        make_stacked_planewave_pair, padded_pack_tables,
                        sphere_for_cutoff)


@pytest.fixture(scope="module")
def sph16():
    return SphereDomain.from_diameter(16)


def test_sphere_extents_and_cutoff(sph16):
    assert sph16.extents == (16, 16, 16)
    # every packed point satisfies |g - c|² ≤ r² (the paper's E_cut rule)
    m = sph16.mask()
    idx = np.argwhere(m)
    c = np.asarray(sph16.center)
    assert (((idx - c) ** 2).sum(1) <= sph16.radius ** 2 + 1e-9).all()


def test_sphere_occupancy_close_to_pi_over_6(sph16):
    # sphere volume / cube volume = π/6 ≈ 0.524
    occ = sph16.npacked / 16 ** 3
    assert 0.45 < occ < 0.58


def test_csr_offsets_consistent(sph16):
    off = sph16.offsets
    lens = off["z_hi"] - off["z_lo"]
    assert (lens > 0).all()
    assert off["row_ptr"][-1] == sph16.npacked
    np.testing.assert_array_equal(np.diff(off["row_ptr"]), lens)
    # xy projection is a disk: per-x column counts are symmetric
    xs = off["col_x"]
    counts = np.bincount(xs, minlength=16)
    np.testing.assert_array_equal(counts, counts[::-1])


def test_pack_indices_bijective(sph16):
    idx = sph16.pack_indices()
    assert len(np.unique(idx)) == sph16.npacked


def test_pack_unpack_roundtrip(sph16):
    g = ProcGrid.create([1])
    inv, _ = make_planewave_pair(g, 32, sph16, 2)
    rng = np.random.default_rng(0)
    packed = (rng.standard_normal((2, sph16.npacked))
              + 1j * rng.standard_normal((2, sph16.npacked))
              ).astype(np.complex64)
    cube = inv.unpack(jnp.asarray(packed))
    assert cube.shape == (2, 16, 16, 16)
    back = np.asarray(inv.pack(cube))
    np.testing.assert_array_equal(back, packed)
    # everything outside the sphere is zero
    outside = np.asarray(cube)[:, ~sph16.mask()]
    assert np.abs(outside).max() == 0


def test_staged_padding_equals_padded_reference(sph16):
    """The paper's central numerical claim: staged pad+FFT ≡ pad-then-FFT."""
    g = ProcGrid.create([1])
    n = 32
    inv, fwd = make_planewave_pair(g, n, sph16, 2)
    rng = np.random.default_rng(1)
    packed = (rng.standard_normal((2, sph16.npacked))
              + 1j * rng.standard_normal((2, sph16.npacked))
              ).astype(np.complex64)
    cube = np.asarray(inv.unpack(jnp.asarray(packed)))
    full = np.zeros((2, n, n, n), np.complex64)
    full[:, :16, :16, :16] = cube
    ref = np.fft.ifftn(full, axes=(1, 2, 3))
    y = np.asarray(inv(jnp.asarray(cube)))
    np.testing.assert_allclose(y, ref, rtol=3e-4, atol=1e-6)


def test_forward_truncation(sph16):
    g = ProcGrid.create([1])
    n = 32
    _, fwd = make_planewave_pair(g, n, sph16, 2)
    rng = np.random.default_rng(2)
    x = (rng.standard_normal((2, n, n, n))
         + 1j * rng.standard_normal((2, n, n, n))).astype(np.complex64)
    y = np.asarray(fwd(jnp.asarray(x)))
    ref = np.fft.fftn(x, axes=(1, 2, 3))[:, :16, :16, :16]
    np.testing.assert_allclose(y, ref, rtol=3e-4,
                               atol=3e-3 * np.abs(ref).max())


def test_roundtrip_identity_on_sphere(sph16):
    g = ProcGrid.create([1])
    inv, fwd = make_planewave_pair(g, 32, sph16, 2)
    rng = np.random.default_rng(3)
    packed = (rng.standard_normal((2, sph16.npacked))
              + 1j * rng.standard_normal((2, sph16.npacked))
              ).astype(np.complex64)
    cube = inv.unpack(jnp.asarray(packed))
    rt = fwd(inv(cube))
    got = np.asarray(inv.pack(inv.mask_cube(rt)))
    np.testing.assert_allclose(got, packed, rtol=1e-3, atol=2e-5)


# ------------------------------------------------------ ragged k batches
def _ragged_spheres():
    """Two spheres sharing one bounding box with distinct point sets —
    the k-shifted-center situation the ragged batch layer exists for."""
    s0 = SphereDomain.from_diameter(8)
    s1 = SphereDomain(radius=4.0, center=(3.9, 3.9, 3.9), lower=(0, 0, 0),
                      upper=(7, 7, 7))
    assert s0.npacked != s1.npacked
    return [s0, s1]


def test_padded_pack_tables_dump_slot_and_validity():
    spheres = _ragged_spheres()
    idx, valid = padded_pack_tables(spheres)
    npmax = max(s.npacked for s in spheres)
    assert idx.shape == valid.shape == (2, npmax)
    dump = 8 * 8 * 8
    for k, s in enumerate(spheres):
        np.testing.assert_array_equal(idx[k, :s.npacked], s.pack_indices())
        assert (idx[k, s.npacked:] == dump).all()    # padded → dump slot
        assert valid[k, :s.npacked].all()
        assert not valid[k, s.npacked:].any()
    with pytest.raises(ValueError, match="bounding box"):
        padded_pack_tables([spheres[0], SphereDomain.from_diameter(6)])


def test_stacked_pair_matches_per_sphere_reference():
    """The stacked ragged batch reproduces each sphere's own plan pair —
    padding changes the batch shape, never the numbers."""
    g = ProcGrid.create([1])
    spheres = _ragged_spheres()
    nb, n = 2, 16
    inv, fwd = make_stacked_planewave_pair(g, n, spheres, nb)
    assert inv.nk == 2 and inv.npacked_max == max(s.npacked
                                                  for s in spheres)
    assert 0.0 < inv.padding_fraction < 0.5
    rng = np.random.default_rng(5)
    blocks = [jnp.asarray((rng.standard_normal((nb, s.npacked))
                           + 1j * rng.standard_normal((nb, s.npacked))
                           ).astype(np.complex64)) for s in spheres]
    psi = inv(inv.unpack(inv.stack(blocks)))
    assert psi.shape == (2 * nb, n, n, n)
    back = inv.split(inv.pack(fwd(psi)))
    for k, s in enumerate(spheres):
        pinv, pfwd = make_planewave_pair(g, n, s, nb)
        ref = pinv(pinv.unpack(blocks[k]))
        np.testing.assert_array_equal(
            np.asarray(psi[k * nb:(k + 1) * nb]), np.asarray(ref))
        np.testing.assert_allclose(np.asarray(back[k]),
                                   np.asarray(blocks[k]),
                                   rtol=1e-3, atol=2e-5)


def test_stacked_pair_shares_inner_plan_and_accounts_tables():
    """plan= wraps an existing d³→n³ FftPlan (no second build); the ragged
    tables are private bytes on top of the shared DFT-matrix tables."""
    from repro.core import FftPlan
    g = ProcGrid.create_abstract([1])
    spheres = _ragged_spheres()
    inv0, _ = make_stacked_planewave_pair(g, 16, spheres, 2)
    searches = FftPlan.searches
    inv, fwd = make_stacked_planewave_pair(g, 16, spheres, 2,
                                           plan=inv0.plan)
    assert inv.plan is inv0.plan
    assert FftPlan.searches == searches          # wrapped, not re-planned
    assert fwd.plan is inv0.plan.inverse()
    tables = int(inv._pad_idx.nbytes) + int(inv._valid.nbytes)
    assert inv.private_bytes() >= tables
    assert inv.estimated_bytes() == inv.private_bytes() + sum(
        inv.shared_table_bytes().values())
    assert inv.shared_table_bytes() == inv0.plan.shared_table_bytes()
    assert "Stacked" in inv.describe()


def test_padded_kinetic_table_matches_perk_ladders():
    """The dense (nk, npacked_max) kinetic table agrees bitwise with the
    per-k ladders on valid lanes and is exactly zero on padded lanes."""
    import numpy as np
    from repro.core import padded_kinetic_table
    from repro.dft import PlaneWaveBasis
    g = ProcGrid.create([1], ["pw_kin"])
    b = PlaneWaveBasis(16, kpts=((0, 0, 0), (0.5, 0.5, 0.5)), nbands=2,
                       grid=g)
    kin, valid = padded_kinetic_table(b.spheres, b.L)
    assert kin.shape == valid.shape == (2, b.npacked_max)
    for ik in range(2):
        npk = b.npacked(ik)
        assert valid[ik, :npk].all() and not valid[ik, npk:].any()
        np.testing.assert_array_equal(kin[ik, :npk],
                                      np.asarray(b.kinetic(ik)))
        assert (kin[ik, npk:] == 0.0).all()


def test_staged_moves_less_data_than_padded():
    """Fig. 9 mechanism: the staged transform's transpose moves ≥4× less."""
    from repro.core import Domain, DistTensor, FftPlan
    g = ProcGrid.create_abstract([4])
    n = 32
    sph = sphere_for_cutoff(n)            # d = 16
    inv, _ = make_planewave_pair(g, n, sph, 4)
    staged = sum(s["bytes_per_device"] for s in inv.comm_stats())
    b = Domain((0,), (3,))
    cube = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
    ti = DistTensor.create((b, cube), "b x{0} y z", g)
    to = DistTensor.create((b, cube), "B X Y Z{0}", g)
    padded = FftPlan(ti, to, [("x", "X"), ("y", "Y"), ("z", "Z")],
                     inverse=True)
    full = sum(s["bytes_per_device"] for s in padded.comm_stats())
    assert staged * 4 <= full


def test_staged_fewer_flops_than_padded():
    from repro.core import Domain, DistTensor, FftPlan
    g = ProcGrid.create([1])
    n = 32
    sph = sphere_for_cutoff(n)
    inv, _ = make_planewave_pair(g, n, sph, 4)
    b = Domain((0,), (3,))
    cube = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
    ti = DistTensor.create((b, cube), "b x{0} y z", g)
    to = DistTensor.create((b, cube), "B X Y Z{0}", g)
    padded = FftPlan(ti, to, [("x", "X"), ("y", "Y"), ("z", "Z")],
                     inverse=True)
    assert inv.flop_count() < padded.flop_count() * 0.65


def test_distributed_planewave(dist):
    script = """
import numpy as np, jax.numpy as jnp
from repro.core import ProcGrid, SphereDomain, make_planewave_pair
g = ProcGrid.create([8])
n = 32
sph = SphereDomain.from_diameter(16)
inv, fwd = make_planewave_pair(g, n, sph, 4)
rng = np.random.default_rng(1)
packed = (rng.standard_normal((4, sph.npacked))
          + 1j*rng.standard_normal((4, sph.npacked))).astype(np.complex64)
cube = np.asarray(inv.unpack(jnp.asarray(packed)))
full = np.zeros((4, n, n, n), np.complex64); full[:, :16, :16, :16] = cube
ref = np.fft.ifftn(full, axes=(1,2,3))
y = np.asarray(inv(jnp.asarray(cube)))
assert np.abs(y-ref).max() / np.abs(ref).max() < 5e-6
print("OK")
"""
    assert "OK" in dist(script)


def test_batch_plus_fft_grid_2d(dist):
    """2D processing grid: batch axis × fft axis (paper's >dims scaling)."""
    script = """
import numpy as np, jax.numpy as jnp
from repro.core import ProcGrid, SphereDomain, make_planewave_pair
g = ProcGrid.create([2, 4])
n = 32
sph = SphereDomain.from_diameter(16)
inv, fwd = make_planewave_pair(g, n, sph, 4, batch_axes=(0,), fft_axes=(1,))
rng = np.random.default_rng(1)
packed = (rng.standard_normal((4, sph.npacked))
          + 1j*rng.standard_normal((4, sph.npacked))).astype(np.complex64)
cube = np.asarray(inv.unpack(jnp.asarray(packed)))
full = np.zeros((4, n, n, n), np.complex64); full[:, :16, :16, :16] = cube
ref = np.fft.ifftn(full, axes=(1,2,3))
y = np.asarray(inv(jnp.asarray(cube)))
assert np.abs(y-ref).max() / np.abs(ref).max() < 5e-6
print("OK")
"""
    assert "OK" in dist(script)
