"""Per-architecture smoke tests (reduced configs) + decode/forward parity."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, SHAPES, all_configs, applicable, \
    get_config
from repro.models.model_zoo import build
from repro.models.transformer import logits_fn

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (B, S)), jnp.int32)}
    b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    if cfg.family == "vlm":
        b["image_embeds"] = jnp.full((B, cfg.n_img_tokens, cfg.d_model),
                                     0.01, jnp.float32)
    if cfg.family == "encdec":
        b["frames"] = jnp.full((B, cfg.enc_seq, cfg.d_model), 0.01,
                               jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_opt_state, make_train_step
    cfg = get_config(arch).reduced()
    m = build(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    h = m.forward(params, batch)
    S_out = 32 + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    assert h.shape == (2, S_out, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    step = make_train_step(m, AdamWConfig(warmup_steps=0, total_steps=10),
                           donate=False)
    opt = init_opt_state(params)
    p2, o2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(o2["step"]) == 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, p2))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forced(arch):
    """Token-by-token decode logits == full forward logits (per family)."""
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.n_experts))
    m = build(cfg)
    params = m.init(KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    h = m.forward(params, batch)
    full = logits_fn(params, h, cfg)
    extra = cfg.n_img_tokens if cfg.family == "vlm" else 0
    P = S // 2
    cache = m.init_cache(B, S + extra + 2, jnp.float32)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :P]
    lg, cache = m.prefill(params, pre, cache)
    errs = [float(jnp.abs(lg[:, 0] - full[:, extra + P - 1]).max())]
    lengths = jnp.full((B,), P + extra, jnp.int32)
    for t in range(P, S):
        lg, cache = m.decode(params, batch["tokens"][:, t:t + 1], cache,
                             lengths)
        lengths = lengths + 1
        errs.append(float(jnp.abs(lg[:, 0] - full[:, extra + t]).max()))
    assert max(errs) < 5e-5, f"{arch}: {errs}"


def test_moe_capacity_drops_tokens_gracefully():
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              capacity_factor=0.5)
    m = build(cfg)
    params = m.init(KEY)
    loss = m.loss(params, _batch(cfg))
    assert bool(jnp.isfinite(loss))


def test_local_window_attention_masks_past():
    """Hybrid local attention must ignore tokens beyond the window."""
    cfg = dataclasses.replace(get_config("recurrentgemma-9b").reduced(),
                              local_window=4)
    m = build(cfg)
    params = m.init(KEY)
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, cfg.vocab, (1, 24))
    t2 = t1.copy()
    t2[0, :8] = rng.integers(0, cfg.vocab, 8)   # perturb far past
    h1 = m.forward(params, {"tokens": jnp.asarray(t1, jnp.int32)})
    h2 = m.forward(params, {"tokens": jnp.asarray(t2, jnp.int32)})
    # hybrid recurrence carries state, so allow small drift; attention
    # itself is windowed — late positions must NOT match for rglru but the
    # attention contribution of tokens <8 is zero. Check instead that a
    # pure-attention model with a window is exactly invariant:
    dcfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced())
    from repro.models.attention import blocked_attention
    q = jnp.asarray(rng.standard_normal((1, 24, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 24, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 24, 2, 8)), jnp.float32)
    o1 = blocked_attention(q, k, v, causal=True, window=4, block=8)
    k2 = k.at[:, :8].set(0.0)
    v2 = v.at[:, :8].set(0.0)
    o2 = blocked_attention(q, k2, v2, causal=True, window=4, block=8)
    np.testing.assert_allclose(np.asarray(o1[:, 16:]),
                               np.asarray(o2[:, 16:]), atol=1e-6)


def test_blocked_attention_matches_naive():
    from repro.models.attention import blocked_attention
    rng = np.random.default_rng(5)
    B, S, H, Kh, D = 2, 32, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Kh, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Kh, D)), jnp.float32)
    o = blocked_attention(q, k, v, causal=True, block=8)
    # naive reference
    kr = jnp.repeat(k, H // Kh, 2)
    vr = jnp.repeat(v, H // Kh, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(D)
    mask = np.tril(np.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=2e-5)


def test_ssd_chunked_matches_sequential_scan():
    """Mamba-2 SSD chunked dual form vs naive recurrence."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(6)
    B, S, H, P, N = 1, 32, 2, 4, 8
    x = rng.standard_normal((B, S, H, P)).astype(np.float32)
    dt = np.abs(rng.standard_normal((B, S, H))).astype(np.float32) * 0.5
    A = -np.abs(rng.standard_normal(H)).astype(np.float32)
    Bm = rng.standard_normal((B, S, N)).astype(np.float32)
    Cm = rng.standard_normal((B, S, N)).astype(np.float32)
    y = np.asarray(ssd_chunked(*map(jnp.asarray, (x, dt, A, Bm, Cm)), 8))
    # sequential reference
    s = np.zeros((B, H, N, P), np.float32)
    ref = np.zeros_like(x)
    for t in range(S):
        dA = np.exp(dt[:, t] * A)                       # (B,H)
        s = s * dA[..., None, None] + np.einsum(
            "bn,bh,bhp->bhnp", Bm[:, t], dt[:, t], x[:, t])
        ref[:, t] = np.einsum("bn,bhnp->bhp", Cm[:, t], s)
    np.testing.assert_allclose(y, ref, rtol=1e-3, atol=1e-3)


def test_rglru_scan_matches_sequential():
    from repro.models.rglru import rglru_block, rglru_init, rglru_init_state
    from repro.configs.base import get_config
    cfg = get_config("recurrentgemma-9b").reduced()
    p = rglru_init(KEY, cfg, jnp.float32)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 12, cfg.d_model)), jnp.float32)
    y_par, _ = rglru_block(p, x, cfg)
    st = rglru_init_state(cfg, 2)
    outs = []
    for t in range(12):
        y, st = rglru_block(p, x[:, t:t + 1], cfg, state=st)
        outs.append(np.asarray(y))
    y_seq = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), y_seq, rtol=1e-4,
                               atol=1e-4)


def test_long_500k_applicability_rule():
    ok = {a: applicable(get_config(a), SHAPES["long_500k"])[0]
          for a in ARCH_IDS}
    assert ok == {
        "qwen3-32b": False, "tinyllama-1.1b": False,
        "nemotron-4-340b": False, "granite-3-2b": False,
        "pixtral-12b": False, "granite-moe-3b-a800m": False,
        "dbrx-132b": False, "whisper-small": False,
        "recurrentgemma-9b": True, "mamba2-370m": True,
    }


def test_param_counts_match_analytic():
    for arch in ["tinyllama-1.1b", "mamba2-370m"]:
        cfg = get_config(arch).reduced()
        m = build(cfg)
        params = m.init(KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)


def test_full_config_param_counts_sane():
    # published sizes (±20%: head_dim/tie conventions differ)
    expect = {"tinyllama-1.1b": 1.1e9, "qwen3-32b": 32e9,
              "nemotron-4-340b": 340e9, "dbrx-132b": 132e9,
              "mamba2-370m": 370e6}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)


def test_fft_conv_option_for_mamba():
    """conv_impl='fft' (FFTB integration) ≡ direct conv."""
    cfg = get_config("mamba2-370m").reduced()
    m1 = build(cfg)
    params = m1.init(KEY)
    b = _batch(cfg, 2, 16)
    h1 = m1.forward(params, b)
    cfg2 = dataclasses.replace(cfg, conv_impl="fft")
    m2 = build(cfg2)
    h2 = m2.forward(params, b)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3,
                               atol=2e-3)
