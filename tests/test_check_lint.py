"""Seeded-violation fixtures for every repo-invariant lint rule.

Each rule gets a minimal source string that *must* trip it (the seeded
violation), a close sibling that must *not* (the rule's precision), and a
``# noqa: FFTB2xx`` escape hatch.  Plus the meta-test: the shipped tree
itself lints clean.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

from repro.check.lint import lint_paths, lint_source

REPO = pathlib.Path(__file__).parent.parent


def codes(diags):
    return [d.code for d in diags]


def lint(src, **kw):
    return lint_source(textwrap.dedent(src), "mod.py", **kw)


# -------------------------------------------------- FFTB201 host sync
def test_host_sync_under_jit_decorator():
    diags = lint("""
        import jax

        @jax.jit
        def step(x):
            y = x * 2
            return float(jax.numpy.sum(y))
    """)
    assert codes(diags) == ["FFTB201"]
    assert "host sync" in diags[0].message
    assert diags[0].location.startswith("mod.py:")


def test_host_sync_reachable_through_helper():
    # the sync lives in a helper the jitted root calls — reachability
    diags = lint("""
        import jax

        def _inner(y):
            return y.block_until_ready()

        @jax.jit
        def step(x):
            return _inner(x * 2)
    """)
    assert codes(diags) == ["FFTB201"]
    assert "_inner" in diags[0].message


def test_host_sync_outside_traced_code_is_fine():
    assert lint("""
        def eager_report(x):
            return float(sum_of(x))
    """) == []


def test_host_sync_known_traced_root_names():
    # jit_step is a cross-module traced root even without a decorator
    diags = lint("""
        import numpy as np

        def jit_step(state):
            return np.asarray(state.rho)
    """)
    assert codes(diags) == ["FFTB201"]


def test_host_sync_noqa_suppresses():
    assert lint("""
        import jax

        @jax.jit
        def step(x):
            return float(host_only(x))  # noqa: FFTB201
    """) == []


# ------------------------------------------------ FFTB202 plan builds
def test_plan_build_under_tracing():
    diags = lint("""
        def _execute_traced(basis, s):
            plans = basis.stacked_hamiltonian_plans(s)
            return plans
    """)
    assert codes(diags) == ["FFTB202"]
    assert "stacked_hamiltonian_plans" in diags[0].message


def test_plan_build_passed_by_name_to_jit():
    diags = lint("""
        import jax

        def body(carry):
            return cache.get_or_build(key, build)

        run = jax.jit(body)
    """)
    assert codes(diags) == ["FFTB202"]


def test_plan_build_eager_fetch_is_fine():
    assert lint("""
        def make_step(basis, s):
            plans = basis.stacked_hamiltonian_plans(s)   # eager: at trace
            def jit_step(x):
                return plans[0](x)
            return jit_step
    """) == []


# ------------------------------------------------ FFTB203 honest clock
def test_time_time_interval():
    diags = lint("""
        import time

        def bench(f):
            t0 = time.time()
            f()
            return time.time() - t0
    """)
    assert codes(diags) == ["FFTB203"]
    assert "perf_counter" in diags[0].hint


def test_time_time_epoch_stamp_is_fine():
    assert lint("""
        import time

        def checkpoint_meta():
            return {"saved_at": time.time()}
    """) == []


# ------------------------------------------- FFTB204 unsynced window
def test_perf_counter_window_without_sync():
    diags = lint("""
        import time
        import jax.numpy as jnp

        def bench(x):
            t0 = time.perf_counter()
            y = jnp.fft.fftn(x)
            return time.perf_counter() - t0
    """)
    assert codes(diags) == ["FFTB204"]
    assert "measures dispatch" in diags[0].hint


def test_perf_counter_window_with_sync_is_fine():
    assert lint("""
        import time
        import jax.numpy as jnp

        def bench(x):
            t0 = time.perf_counter()
            y = jnp.fft.fftn(x).block_until_ready()
            return time.perf_counter() - t0
    """) == []


def test_perf_counter_window_float_materializes():
    # float(...) pulls to host — counts as the sync (trainer.py pattern)
    assert lint("""
        import time
        import jax.numpy as jnp

        def bench(x):
            t0 = time.perf_counter()
            loss = float(jnp.sum(x))
            return time.perf_counter() - t0
    """) == []


# ---------------------------------------------- FFTB205 bare locks
def test_bare_lock_on_serving_path():
    src = """
        import threading

        class Scheduler:
            def __init__(self):
                self._lock = threading.Lock()
    """
    diags = lint_source(textwrap.dedent(src), "src/repro/serve/sched.py")
    assert codes(diags) == ["FFTB205"]
    assert "TrackedLock" in diags[0].hint


def test_bare_lock_elsewhere_is_fine():
    src = """
        import threading
        lock = threading.RLock()
    """
    assert lint_source(textwrap.dedent(src), "src/repro/obs/metrics.py") == []


def test_bare_lock_locks_module_exempt():
    src = "import threading\n_graph = threading.Lock()\n"
    assert lint_source(src, "src/repro/check/locks.py") == []


# -------------------------------------------------------- meta checks
def test_syntax_error_is_reported_not_raised():
    diags = lint_source("def broken(:\n", "bad.py")
    assert codes(diags) == ["FFTB201"]
    assert "cannot parse" in diags[0].message


def test_extra_roots_extend_reachability():
    src = """
        def my_kernel(x):
            return x.item()
    """
    assert lint(src) == []
    assert codes(lint(src, extra_roots=("my_kernel",))) == ["FFTB201"]


def test_shipped_tree_lints_clean():
    """The invariant the CI job gates on: src/ has zero lint errors."""
    diags = lint_paths([REPO / "src"])
    errors = [d for d in diags if d.is_error]
    assert not errors, "\n".join(d.render() for d in errors)


def test_cli_lint_and_codes_subcommands():
    env_src = str(REPO / "src")
    env = {**os.environ, "PYTHONPATH": env_src}
    out = subprocess.run(
        [sys.executable, "-m", "repro.check", "lint", env_src],
        capture_output=True, text=True, env=env, check=False)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 error(s)" in out.stdout
    out = subprocess.run(
        [sys.executable, "-m", "repro.check", "codes"],
        capture_output=True, text=True, env=env, check=False)
    assert out.returncode == 0
    assert "FFTB301" in out.stdout
