"""build(cfg) → ModelBundle: one uniform interface over all families.

batch dicts:
  dense/moe/ssm/hybrid : {"tokens", "labels"}
  vlm                  : + {"image_embeds" (B, n_img, D)}  (stub frontend)
  encdec               : {"frames" (B, enc_seq, D), "tokens", "labels"}
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import flags


def _scan(f, init, xs, **kw):
    kw.setdefault("unroll", True if flags.scan_unroll() else 1)
    return jax.lax.scan(f, init, xs, **kw)

from . import encdec, rglru, ssm, transformer
from .layers import dense_init, mlp_apply, mlp_init, rms_norm
from .transformer import _dtype, _remat, init_layer, layer_apply, logits_fn
from repro.sharding import ctx


# ------------------------------------------------------------------ loss
def chunked_xent(params, h, labels, cfg, chunk: int = 512,
                 mask=None):
    """Sequence-chunked softmax cross-entropy; never materializes
    (B, S, V) — logits are built per chunk (vocab stays model-sharded) and
    the chunk body is rematerialized in the backward pass."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    nch = S // chunk

    @jax.checkpoint
    def body(tot, idx):
        hc = jax.lax.dynamic_slice_in_dim(h, idx * chunk, chunk, 1)
        lc = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, 1)
        hc = ctx.constrain(hc, "batch", None, None)
        logits = logits_fn(params, hc, cfg)               # (B,chunk,V) f32
        logits = ctx.constrain(logits, "batch", None, "model")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mask is not None:
            mc = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, 1)
            nll = nll * mc
        return tot + nll.sum(), None

    total, _ = _scan(body, jnp.zeros((), jnp.float32),
                            jnp.arange(nch))
    denom = B * S if mask is None else None
    if denom is None:
        return total / jnp.maximum(mask.sum(), 1.0)
    return total / denom


# ------------------------------------------------------------ SSM family
def ssm_init_params(key, cfg):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    lkeys = jax.random.split(ks[0], cfg.n_layers)

    def one(k):
        return {"ln": jnp.zeros((cfg.d_model,), jnp.float32),
                "ssm": ssm.ssm_init(k, cfg, dt)}

    p = {
        "embed": dense_init(ks[1], (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dt),
        "layers": jax.vmap(one)(lkeys),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab),
                                  scale=0.02, dtype=dt)
    return p


def ssm_forward(params, tokens, cfg):
    x = ctx.constrain_act(params["embed"][tokens])

    def body(x, lp):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y, _ = ssm.ssm_block(lp["ssm"], h, cfg)
        return ctx.constrain_act(x + y), None

    x, _ = _scan(_remat(body, cfg), x, params["layers"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def ssm_init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    st = ssm.ssm_init_state(cfg, batch, dtype)
    L = cfg.n_layers
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), st)


def ssm_prefill(params, tokens, cfg, cache):
    x = params["embed"][tokens]

    def body(x, scans):
        lp, st = scans
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y, new_st = ssm.ssm_block(lp["ssm"], h, cfg, state=st)
        return x + y, new_st

    x, new_cache = _scan(_remat(body, cfg), x,
                                (params["layers"], cache))
    return rms_norm(x, params["ln_f"], cfg.norm_eps), new_cache


def ssm_decode(params, tokens, cfg, cache, lengths):
    x = params["embed"][tokens]

    def body(x, scans):
        lp, st = scans
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        y, new_st = ssm.ssm_block(lp["ssm"], h, cfg, state=st)
        return x + y, new_st

    x, new_cache = _scan(body, x, (params["layers"], cache))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return logits_fn(params, h, cfg), new_cache


# --------------------------------------------------------- hybrid family
def _hybrid_counts(cfg):
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    n_tail = cfg.n_layers - n_groups * len(pat)
    return n_groups, n_tail


def _rec_init(key, cfg, dt):
    ks = jax.random.split(key, 2)
    return {"ln": jnp.zeros((cfg.d_model,), jnp.float32),
            "rglru": rglru.rglru_init(ks[0], cfg, dt),
            "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.activation,
                            dt)}


def hybrid_init_params(key, cfg):
    dt = _dtype(cfg)
    n_groups, n_tail = _hybrid_counts(cfg)
    ks = jax.random.split(key, 4)

    def group(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"rec1": _rec_init(k1, cfg, dt),
                "rec2": _rec_init(k2, cfg, dt),
                "attn": init_layer(k3, cfg)}

    p = {
        "embed": dense_init(ks[1], (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dt),
        "groups": jax.vmap(group)(jax.random.split(ks[0], n_groups)),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if n_tail:
        p["tail"] = jax.vmap(lambda k: _rec_init(k, cfg, dt))(
            jax.random.split(ks[2], n_tail))
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab),
                                  scale=0.02, dtype=dt)
    return p


def _rec_apply(p, x, cfg, state=None):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    y, new_state = rglru.rglru_block(p["rglru"], h, cfg, state=state)
    x = x + y
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + mlp_apply(p["mlp"], h, cfg.activation), new_state


def hybrid_forward(params, tokens, cfg):
    x = ctx.constrain_act(params["embed"][tokens])
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, gp):
        x, _ = _rec_apply(gp["rec1"], x, cfg)
        x, _ = _rec_apply(gp["rec2"], x, cfg)
        x, _ = layer_apply(gp["attn"], x, cfg, positions,
                           window=cfg.local_window)
        return ctx.constrain_act(x), None

    x, _ = _scan(_remat(body, cfg), x, params["groups"])
    if "tail" in params:
        def tbody(x, tp):
            x, _ = _rec_apply(tp, x, cfg)
            return x, None
        x, _ = _scan(_remat(tbody, cfg), x, params["tail"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def hybrid_init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    n_groups, n_tail = _hybrid_counts(cfg)
    W = min(cfg.local_window or capacity, capacity)
    rec = rglru.rglru_init_state(cfg, batch)
    cache = {
        "rec1": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(), rec),
        "rec2": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(), rec),
        "k": jnp.zeros((n_groups, batch, W, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((n_groups, batch, W, cfg.n_kv, cfg.head_dim), dtype),
    }
    if n_tail:
        cache["tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_tail,) + a.shape).copy(), rec)
    return cache


def _hybrid_attn_prefill(lp, x, cfg, positions, ck, cv):
    """Local-attention sub-block; fills the ring cache (capacity W) with the
    last W roped keys/values at slots = position % W (ring invariant)."""
    from .attention import blocked_attention
    from .layers import apply_rope
    B, S, D = x.shape
    H, Kh, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    W = ck.shape[1]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = (h @ lp["wq"]).reshape(B, S, H, hd)
    k = (h @ lp["wk"]).reshape(B, S, Kh, hd)
    v = (h @ lp["wv"]).reshape(B, S, Kh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = blocked_attention(q, k, v, causal=True, window=cfg.local_window)
    x = x + o.reshape(B, S, H * hd) @ lp["wo"]
    tail = min(W, S)
    slots = (jnp.arange(S - tail, S)) % W
    ck = ck.at[:, slots].set(k[:, -tail:].astype(ck.dtype))
    cv = cv.at[:, slots].set(v[:, -tail:].astype(cv.dtype))
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h2, cfg.activation), ck, cv


def _hybrid_attn_decode(lp, x, cfg, ck, cv, lengths):
    """Single-token local attention against the ring cache."""
    from .attention import decode_attention
    from .layers import apply_rope
    B = x.shape[0]
    H, Kh, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    W = ck.shape[1]
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = (h @ lp["wq"]).reshape(B, 1, H, hd)
    k = (h @ lp["wk"]).reshape(B, 1, Kh, hd)
    v = (h @ lp["wv"]).reshape(B, 1, Kh, hd)
    q = apply_rope(q, lengths[:, None], cfg.rope_theta)
    k = apply_rope(k, lengths[:, None], cfg.rope_theta)
    slot = lengths % W
    bidx = jnp.arange(B)
    ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
    cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
    filled = jnp.minimum(lengths + 1, W)
    o = decode_attention(q, ck, cv, filled)
    x = x + o.reshape(B, 1, H * hd) @ lp["wo"]
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h2, cfg.activation), ck, cv


def hybrid_prefill(params, tokens, cfg, cache):
    x = params["embed"][tokens]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, scans):
        gp, r1, r2, ck, cv = scans
        x, nr1 = _rec_apply(gp["rec1"], x, cfg, state=r1)
        x, nr2 = _rec_apply(gp["rec2"], x, cfg, state=r2)
        x, ck, cv = _hybrid_attn_prefill(gp["attn"], x, cfg, positions,
                                         ck, cv)
        return x, (nr1, nr2, ck, cv)

    x, (r1, r2, ck, cv) = _scan(
        _remat(body, cfg), x,
        (params["groups"], cache["rec1"], cache["rec2"],
         cache["k"], cache["v"]))
    new_cache = {"rec1": r1, "rec2": r2, "k": ck, "v": cv}
    if "tail" in params:
        def tbody(x, scans):
            tp, st = scans
            x, nst = _rec_apply(tp, x, cfg, state=st)
            return x, nst
        x, tst = _scan(_remat(tbody, cfg), x,
                              (params["tail"], cache["tail"]))
        new_cache["tail"] = tst
    return rms_norm(x, params["ln_f"], cfg.norm_eps), new_cache


def hybrid_decode(params, tokens, cfg, cache, lengths):
    x = params["embed"][tokens]

    def body(x, scans):
        gp, r1, r2, ck, cv = scans
        x, nr1 = _rec_apply(gp["rec1"], x, cfg, state=r1)
        x, nr2 = _rec_apply(gp["rec2"], x, cfg, state=r2)
        x, ck, cv = _hybrid_attn_decode(gp["attn"], x, cfg, ck, cv, lengths)
        return x, (nr1, nr2, ck, cv)

    x, (r1, r2, ck, cv) = _scan(
        body, x, (params["groups"], cache["rec1"], cache["rec2"],
                  cache["k"], cache["v"]))
    new_cache = {"rec1": r1, "rec2": r2, "k": ck, "v": cv}
    if "tail" in params:
        def tbody(x, scans):
            tp, st = scans
            x, nst = _rec_apply(tp, x, cfg, state=st)
            return x, nst
        x, tst = _scan(tbody, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = tst
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return logits_fn(params, h, cfg), new_cache


def _hybrid_bundle(cfg):
    def fwd(params, batch):
        return hybrid_forward(params, batch["tokens"], cfg)

    def loss(params, batch):
        return chunked_xent(params, fwd(params, batch), batch["labels"], cfg)

    def prefill_fn(params, batch, cache):
        h, cache = hybrid_prefill(params, batch["tokens"], cfg, cache)
        return logits_fn(params, h[:, -1:], cfg), cache

    def decode_fn(params, tokens, cache, lengths):
        return hybrid_decode(params, tokens, cfg, cache, lengths)

    return ModelBundle(
        cfg=cfg,
        init=functools.partial(hybrid_init_params, cfg=cfg),
        forward=fwd, loss=loss,
        init_cache=functools.partial(hybrid_init_cache, cfg),
        prefill=prefill_fn,
        decode=decode_fn)


def build(cfg):
    return _BUILDERS[cfg.family](cfg)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: Any
    init: Callable
    forward: Callable                 # (params, batch) -> hidden
    loss: Callable                    # (params, batch) -> scalar
    init_cache: Callable              # (batch, capacity, dtype) -> cache
    prefill: Callable                 # (params, batch, cache) -> (h, cache)
    decode: Callable                  # (params, tok, cache, len) -> (lg, c)


def _lm_bundle(cfg):
    def fwd(params, batch):
        return transformer.forward(params, batch["tokens"], cfg,
                                   embeds=batch.get("image_embeds"))

    def loss(params, batch):
        h = fwd(params, batch)
        labels = batch["labels"]
        if cfg.family == "vlm":
            h = h[:, -labels.shape[1]:]       # loss over text positions only
        return chunked_xent(params, h, labels, cfg)

    def prefill_fn(params, batch, cache):
        h, cache = transformer.prefill(params, batch["tokens"], cfg, cache,
                                       embeds=batch.get("image_embeds"))
        return logits_fn(params, h[:, -1:], cfg), cache

    def decode_fn(params, tokens, cache, lengths):
        return transformer.decode_step(params, tokens, cfg, cache, lengths)

    return ModelBundle(
        cfg=cfg,
        init=functools.partial(transformer.init_params, cfg=cfg),
        forward=fwd, loss=loss,
        init_cache=functools.partial(transformer.init_cache, cfg),
        prefill=prefill_fn, decode=decode_fn)


def _ssm_bundle(cfg):
    def fwd(params, batch):
        return ssm_forward(params, batch["tokens"], cfg)

    def loss(params, batch):
        return chunked_xent(params, fwd(params, batch), batch["labels"], cfg)

    def prefill_fn(params, batch, cache):
        h, cache = ssm_prefill(params, batch["tokens"], cfg, cache)
        return logits_fn(params, h[:, -1:], cfg), cache

    def decode_fn(params, tokens, cache, lengths):
        return ssm_decode(params, tokens, cfg, cache, lengths)

    return ModelBundle(
        cfg=cfg,
        init=functools.partial(ssm_init_params, cfg=cfg),
        forward=fwd, loss=loss,
        init_cache=functools.partial(ssm_init_cache, cfg),
        prefill=prefill_fn,
        decode=decode_fn)


def _encdec_bundle(cfg):
    def fwd(params, batch):
        enc = encdec.encode(params, batch["frames"], cfg)
        return encdec.decode_train(params, batch["tokens"], enc, cfg)

    def loss(params, batch):
        return chunked_xent(params, fwd(params, batch), batch["labels"], cfg)

    def prefill_fn(params, batch, cache):
        h, cache = encdec.prefill(params, batch["tokens"], batch["frames"],
                                  cfg, cache)
        return logits_fn(params, h[:, -1:], cfg), cache

    def decode_fn(params, tokens, cache, lengths):
        return encdec.decode_step(params, tokens, cfg, cache, lengths)

    return ModelBundle(
        cfg=cfg,
        init=functools.partial(encdec.init_params, cfg=cfg),
        forward=fwd, loss=loss,
        init_cache=functools.partial(encdec.init_cache, cfg),
        prefill=prefill_fn, decode=decode_fn)


_BUILDERS = {
    "dense": _lm_bundle,
    "moe": _lm_bundle,
    "vlm": _lm_bundle,
    "ssm": _ssm_bundle,
    "encdec": _encdec_bundle,
    "hybrid": _hybrid_bundle,
}
