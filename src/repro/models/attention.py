"""Attention: blocked (online-softmax) GQA with causal/local/full masking,
plus the single-token decode path against a KV cache.

Two TPU-fleet-critical choices:
  * blocked formulation (lax.scan over KV blocks, running max/sum) keeps
    peak memory at O(S·block) instead of O(S²) — what makes the
    prefill_32k cells lowerable;
  * GQA runs in *flat-H* layout: K/V are repeated to H heads before the
    score einsum, because H (divisible by the 16-way model axis) is the
    only head dim GSPMD can shard fully — the (Kh, G) factored layout caps
    tensor parallelism at Kh(=4..8)-way and replicates the score tensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import flags


def _scan(f, init, xs, **kw):
    kw.setdefault("unroll", True if flags.scan_unroll() else 1)
    return jax.lax.scan(f, init, xs, **kw)

from repro.sharding import ctx

NEG_INF = -1e30


def _repeat_kv(k, H: int):
    """(B, S, Kh, D) → (B, S, H, D) by repeating each kv head G times."""
    Kh = k.shape[2]
    if Kh == H:
        return k
    return jnp.repeat(k, H // Kh, axis=2)


def blocked_attention(q, k, v, *, causal: bool = True,
                      window: int = 0, block: int = 1024,
                      q_offset: int = 0):
    """Memory-safe attention. q: (B,Sq,H,D), k/v: (B,Skv,Kh,D).

    window > 0 → local (sliding-window) causal attention.
    q_offset: absolute position of q[0] relative to k[0] (prefill chunking).
    """
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    q = ctx.constrain(q, "batch", None, "model", None)
    k = ctx.constrain(k, "batch", None, "model", None)
    v = ctx.constrain(v, "batch", None, "model", None)
    scale = D ** -0.5
    block = min(block, Skv)
    while Skv % block:
        block //= 2
    nblk = Skv // block
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, blk_idx):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, blk_idx * block, block, 1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, blk_idx * block, block, 1)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_blk).astype(jnp.float32)
        s = ctx.constrain(s, "batch", "model", None, None) * scale
        k_pos = blk_idx * block + jnp.arange(block)
        mask = jnp.ones((Sq, block), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        # fully-masked rows: s == m_new == NEG_INF → exp(0) = 1; zero them
        p = p * mask[None, None]
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(v.dtype), v_blk).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc), _ = _scan(step, (m0, l0, a0), jnp.arange(nblk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,H,Sq,D)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)      # (B,Sq,H,D)


def decode_attention(q, k_cache, v_cache, length, *, window: int = 0):
    """Single-token attention. q: (B,1,H,D); caches: (B,Smax,Kh,D);
    length: (B,) valid cache lengths (the new token's k/v already written).

    Unlike prefill, the GQA einsum stays FACTORED (q reshaped (Kh, G)) —
    repeating the cache to H heads would materialize G× the KV bytes
    (observed: 48.5 GiB/device on nemotron decode_32k, §Perf iteration).
    The cache stays sharded on head_dim; contraction over the sharded d
    yields partial scores that GSPMD psums — tiny at S=1."""
    B, _, H, D = q.shape
    Smax, Kh = k_cache.shape[1], k_cache.shape[2]
    G = H // Kh
    qg = q.reshape(B, 1, Kh, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32) \
        * (D ** -0.5)                                     # (B,Kh,G,1,Smax)
    pos = jnp.arange(Smax)[None, :]                       # (1,Smax)
    valid = pos < length[:, None]
    if window:
        valid &= pos >= (length[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return out.reshape(B, 1, H, D).astype(q.dtype)
