"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Layers are *scanned* (stacked params, `jax.lax.scan` over the layer dim) so
HLO size and compile time are depth-independent — required to lower 96-layer
nemotron at 32k within this container.  Remat wraps the scan body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import flags


def _scan(f, init, xs, **kw):
    kw.setdefault("unroll", True if flags.scan_unroll() else 1)
    return jax.lax.scan(f, init, xs, **kw)

from . import moe as moe_mod
from .attention import blocked_attention, decode_attention
from .layers import apply_rope, dense_init, mlp_apply, mlp_init, rms_norm
from repro.sharding import ctx


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------- params
def init_layer(key, cfg):
    dt = _dtype(cfg)
    D, H, Kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "ln1": jnp.zeros((D,), jnp.float32),
        "wq": dense_init(ks[0], (D, H * hd), dtype=dt),
        "wk": dense_init(ks[1], (D, Kh * hd), dtype=dt),
        "wv": dense_init(ks[2], (D, Kh * hd), dtype=dt),
        "wo": dense_init(ks[3], (H * hd, D), dtype=dt),
        "ln2": jnp.zeros((D,), jnp.float32),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(ks[4], cfg, dt)
    else:
        p["mlp"] = mlp_init(ks[4], D, cfg.d_ff, cfg.activation, dt)
    return p


def init_params(key, cfg):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    params = {
        "embed": dense_init(ks[1], (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dt),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab),
                                       scale=0.02, dtype=dt)
    return params


# ----------------------------------------------------------------- layer
def attn_apply(p, x, cfg, positions, *, window: int = 0, cache=None,
               lengths=None):
    """Self-attention sublayer.  cache: (k, v) of (B, Smax, Kh, hd) → decode
    (S==1) or prefill (cache returned filled).  Returns (out, new_cache)."""
    B, S, D = x.shape
    H, Kh, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if S > 1:
        # Megatron-SP boundary: gather seq, keep weights sharded; pinning
        # the projections prevents GSPMD gathering full (D, H·hd) weights
        h = ctx.constrain(h, "batch", None, None)

        def pin(t):
            return ctx.constrain(t, "batch", None, "model")
    else:
        def pin(t):
            return t
    q = pin(h @ p["wq"]).reshape(B, S, H, hd)
    k = pin(h @ p["wk"]).reshape(B, S, Kh, hd)
    v = pin(h @ p["wv"]).reshape(B, S, Kh, hd)
    if S == 1:
        # decode: match the hd-sharded KV-cache layout so the cache scatter
        # stays local (unpinned, GSPMD gathered full-hd k/v per layer —
        # 2.25 GiB/layer transients on nemotron decode_32k, §Perf)
        q = ctx.constrain(q, "batch", None, "model", None)
        k = ctx.constrain(k, "batch", None, None, "model")
        v = ctx.constrain(v, "batch", None, None, "model")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        o = blocked_attention(q, k, v, causal=True, window=window)
        new_cache = None
    elif S == 1:                                   # decode step
        ck, cv = cache
        bidx = jnp.arange(B)
        ck = ck.at[bidx, lengths].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[bidx, lengths].set(v[:, 0].astype(cv.dtype))
        o = decode_attention(q, ck, cv, lengths + 1, window=window)
        new_cache = (ck, cv)
    else:                                          # prefill, cache filled
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.astype(ck.dtype), 0, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.astype(cv.dtype), 0, 1)
        o = blocked_attention(q, k, v, causal=True, window=window)
        new_cache = (ck, cv)
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return out, new_cache


def layer_apply(p, x, cfg, positions, *, window: int = 0, cache=None,
                lengths=None):
    a, new_cache = attn_apply(p, x, cfg, positions, window=window,
                              cache=cache, lengths=lengths)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        f = moe_mod.moe_apply(p["moe"], h, cfg)
    else:
        f = mlp_apply(p["mlp"], h, cfg.activation)
    return x + f, new_cache


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# --------------------------------------------------------------- forward
def forward(params, tokens, cfg, *, embeds=None):
    """tokens: (B, S) → final hidden states (B, S, D).

    embeds: optional (B, S_img, D) precomputed frontend embeddings (VLM stub)
    prepended to the token embeddings.
    """
    x = params["embed"][tokens]
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = ctx.constrain_act(x)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        y, _ = layer_apply(lp, x, cfg, positions)
        return ctx.constrain_act(y), None

    x, _ = _scan(_remat(body, cfg), x, params["layers"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def logits_fn(params, h, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (h @ w.astype(h.dtype)).astype(jnp.float32)


# ------------------------------------------------------------- serving
def init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    L, Kh, hd = cfg.n_layers, cfg.n_kv, cfg.head_dim
    shape = (L, batch, capacity, Kh, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, tokens, cfg, cache, *, embeds=None):
    """Forward pass that also fills the KV cache. Returns (hidden, cache)."""
    x = params["embed"][tokens]
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = ctx.constrain_act(x)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, scans):
        lp, ck, cv = scans
        y, (ck, cv) = layer_apply(lp, x, cfg, positions, cache=(ck, cv))
        return ctx.constrain_act(y), (ck, cv)

    x, (ck, cv) = _scan(_remat(body, cfg), x,
                               (params["layers"], cache["k"], cache["v"]))
    return rms_norm(x, params["ln_f"], cfg.norm_eps), {"k": ck, "v": cv}


def decode_step(params, tokens, cfg, cache, lengths):
    """tokens: (B, 1); lengths: (B,) current context lengths.
    Returns (logits (B,1,V), new cache)."""
    x = params["embed"][tokens]
    positions = lengths[:, None]

    def body(x, scans):
        lp, ck, cv = scans
        y, (ck, cv) = layer_apply(lp, x, cfg, positions, cache=(ck, cv),
                                  lengths=lengths)
        return y, (ck, cv)

    x, (ck, cv) = _scan(body, x,
                               (params["layers"], cache["k"], cache["v"]))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return logits_fn(params, h, cfg), {"k": ck, "v": cv}
