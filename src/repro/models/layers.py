"""Shared neural-net building blocks (pure functional, param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import ctx


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = (1.0 / np.sqrt(fan_in)) if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int):
    pos = np.arange(seq)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(0, d, 2) / d)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(pos * div)
    out[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(out)


# ------------------------------------------------------------------- MLP
def mlp_init(key, d_model: int, d_ff: int, activation: str, dtype):
    ks = jax.random.split(key, 3)
    glu = activation in ("swiglu", "geglu")
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype=dtype)}
    if glu:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype=dtype)
    return p


def mlp_apply(p, x, activation: str):
    # pin the TP layout of the intermediate (tokens unsharded, d_ff over
    # "model") — without this, GSPMD resolves the weight-grad contraction
    # against SP-sharded cotangents by replicating full (d_ff, d_model)
    # gradients (observed: 2×5 GiB per layer on nemotron-340b)
    def pin(h):
        if h.ndim == 3:
            return ctx.constrain(h, "batch", None, "model")
        return h
    up = pin(x @ p["w_up"])
    if activation == "swiglu":
        h = jax.nn.silu(pin(x @ p["w_gate"])) * up
    elif activation == "geglu":
        h = jax.nn.gelu(pin(x @ p["w_gate"])) * up
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(up))
    elif activation == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(activation)
    return h @ p["w_down"]


def causal_conv1d(x, w, cache=None):
    """Depthwise causal conv. x: (B, S, C), w: (K, C).

    Returns (y, new_cache) where cache holds the trailing K-1 inputs for
    single-step decode.  With cache=None the left context is zeros (train /
    full prefill).
    """
    K = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_cache = xp[:, -(K - 1):, :] if K > 1 else None
    return y, new_cache


def fft_causal_conv1d(x, w, cache=None):
    """FFTB-backed depthwise causal conv (paper integration point).

    Identical contract to causal_conv1d; uses frequency-domain convolution
    via repro.core.spectral.fft_conv — profitable for long kernels; with
    K=4 it is a correctness-equivalent demonstration path.
    """
    from repro.core.spectral import fft_conv
    K = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    kernel = w[::-1]                       # correlation → convolution flip
    y = fft_conv(xp, kernel, axis=1)[:, K - 1:, :]
    new_cache = xp[:, -(K - 1):, :] if K > 1 else None
    return y, new_cache
