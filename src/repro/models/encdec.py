"""Whisper-style encoder–decoder.

The conv audio frontend is a STUB per the task spec: the model consumes
precomputed frame embeddings (B, enc_seq, d_model).  Encoder layers use
bidirectional blocked attention with sinusoidal positions; decoder layers
use causal self-attention (RoPE — a documented deviation from Whisper's
learned positions, DESIGN.md §5) plus cross-attention over encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import flags


def _scan(f, init, xs, **kw):
    kw.setdefault("unroll", True if flags.scan_unroll() else 1)
    return jax.lax.scan(f, init, xs, **kw)

from .attention import blocked_attention, decode_attention
from .layers import dense_init, mlp_apply, mlp_init, rms_norm, sinusoidal_pos
from .transformer import _dtype, _remat, attn_apply, init_layer, logits_fn
from repro.sharding import ctx


def _init_cross(key, cfg, dt):
    D, H, Kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 5)
    return {
        "ln": jnp.zeros((D,), jnp.float32),
        "wq": dense_init(ks[0], (D, H * hd), dtype=dt),
        "wk": dense_init(ks[1], (D, Kh * hd), dtype=dt),
        "wv": dense_init(ks[2], (D, Kh * hd), dtype=dt),
        "wo": dense_init(ks[3], (H * hd, D), dtype=dt),
    }


def init_params(key, cfg):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    x_keys = jax.random.split(ks[2], cfg.n_layers)
    p = {
        "embed": dense_init(ks[3], (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dt),
        "enc_layers": jax.vmap(lambda k: init_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_layer(k, cfg))(dec_keys),
        "cross": jax.vmap(lambda k: _init_cross(k, cfg, dt))(x_keys),
        "ln_enc": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[4], (cfg.d_model, cfg.vocab),
                                  scale=0.02, dtype=dt)
    return p


# ---------------------------------------------------------------- encoder
def encode(params, frames, cfg):
    """frames: (B, enc_seq, D) stub embeddings → encoder states."""
    B, S, D = frames.shape
    x = frames.astype(_dtype(cfg)) + sinusoidal_pos(S, D).astype(_dtype(cfg))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = (h @ lp["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(B, S, cfg.n_kv, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(B, S, cfg.n_kv, cfg.head_dim)
        o = blocked_attention(q, k, v, causal=False)
        x = x + o.reshape(B, S, -1) @ lp["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return ctx.constrain_act(
            x + mlp_apply(lp["mlp"], h, cfg.activation)), None

    x = ctx.constrain_act(x)
    x, _ = _scan(_remat(body, cfg), x, params["enc_layers"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _cross_kv(xp, enc, cfg):
    B, Se, _ = enc.shape
    k = (enc @ xp["wk"]).reshape(B, Se, cfg.n_kv, cfg.head_dim)
    v = (enc @ xp["wv"]).reshape(B, Se, cfg.n_kv, cfg.head_dim)
    return k, v


def _cross_apply(xp, x, k, v, cfg):
    B, S, D = x.shape
    h = rms_norm(x, xp["ln"], cfg.norm_eps)
    q = (h @ xp["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    o = blocked_attention(q, k, v, causal=False)
    return o.reshape(B, S, -1) @ xp["wo"]


# ---------------------------------------------------------------- decoder
def decode_train(params, tokens, enc, cfg):
    """Teacher-forced decoder pass. tokens: (B, S) → hidden (B, S, D)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, scans):
        lp, xp = scans
        a, _ = attn_apply(lp, x, cfg, positions)
        x = x + a
        k, v = _cross_kv(xp, enc, cfg)
        x = x + _cross_apply(xp, x, k, v, cfg)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return ctx.constrain_act(
            x + mlp_apply(lp["mlp"], h, cfg.activation)), None

    x, _ = _scan(_remat(body, cfg), x,
                        (params["dec_layers"], params["cross"]))
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    L, Kh, hd = cfg.n_layers, cfg.n_kv, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, capacity, Kh, hd), dtype),
        "v": jnp.zeros((L, batch, capacity, Kh, hd), dtype),
        "xk": jnp.zeros((L, batch, cfg.enc_seq, Kh, hd), dtype),
        "xv": jnp.zeros((L, batch, cfg.enc_seq, Kh, hd), dtype),
    }


def prefill(params, tokens, frames, cfg, cache):
    """Encode + teacher-forced decoder prefill; fills self & cross caches."""
    enc = encode(params, frames, cfg)
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, scans):
        lp, xp, ck, cv = scans
        a, (ck, cv) = attn_apply(lp, x, cfg, positions, cache=(ck, cv))
        x = x + a
        k, v = _cross_kv(xp, enc, cfg)
        x = x + _cross_apply(xp, x, k, v, cfg)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp_apply(lp["mlp"], h, cfg.activation), (ck, cv, k, v)

    x, (ck, cv, xk, xv) = _scan(
        _remat(body, cfg), x,
        (params["dec_layers"], params["cross"], cache["k"], cache["v"]))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return h, {"k": ck, "v": cv,
               "xk": xk.astype(cache["xk"].dtype),
               "xv": xv.astype(cache["xv"].dtype)}


def decode_step(params, tokens, cfg, cache, lengths):
    x = params["embed"][tokens]
    B = x.shape[0]

    def body(x, scans):
        lp, xp, ck, cv, xk, xv = scans
        a, (ck, cv) = attn_apply(lp, x, cfg, lengths[:, None],
                                 cache=(ck, cv), lengths=lengths)
        x = x + a
        h = rms_norm(x, xp["ln"], cfg.norm_eps)
        q = (h @ xp["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        xo = decode_attention(
            q, xk.astype(x.dtype), xv.astype(x.dtype),
            jnp.full((B,), xk.shape[1], jnp.int32))
        x = x + xo.reshape(B, 1, -1) @ xp["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp_apply(lp["mlp"], h, cfg.activation), (ck, cv)

    x, (ck, cv) = _scan(
        body, x, (params["dec_layers"], params["cross"], cache["k"],
                  cache["v"], cache["xk"], cache["xv"]))
    h = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return logits_fn(params, h, cfg), {**cache, "k": ck, "v": cv}
