"""Runtime flags for lowering modes.

UNROLL: when True, every lax.scan in the model stack unrolls. Used by the
dry-run *accounting* pass: XLA's cost_analysis counts a while-loop body
ONCE regardless of trip count, so scanned-layer FLOPs/collectives are
invisible. The accounting pass lowers unrolled at reduced depth (L=1, 2)
and extrapolates linearly to the full depth (exact: scan bodies are
homogeneous). Production lowering keeps scans rolled (depth-independent
compile time).
"""
UNROLL = False


def scan_unroll():
    return UNROLL


import contextlib


@contextlib.contextmanager
def unrolled():
    global UNROLL
    old = UNROLL
    UNROLL = True
    try:
        yield
    finally:
        UNROLL = old
