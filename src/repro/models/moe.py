"""Expert-parallel top-k MoE with capacity-based static dispatch.

Dispatch uses scatter/gather index tables instead of the T×E×C one-hot
(which would be ~10¹³ elements at the assigned shapes): per-(token, k) slot
positions come from a cumulative count, tokens beyond an expert's capacity
are dropped (capacity_factor 1.25), and expert FFNs run as one batched
einsum over the expert dim — which GSPMD shards over the "model" axis (EP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init
from repro.sharding import ctx


def moe_init(key, cfg, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    glu = cfg.activation in ("swiglu", "geglu")
    p = {"router": dense_init(ks[0], (D, E), dtype=jnp.float32),
         "w_up": dense_init(ks[1], (E, D, F), dtype=dtype),
         "w_down": dense_init(ks[2], (E, F, D), dtype=dtype)}
    if glu:
        p["w_gate"] = dense_init(ks[3], (E, D, F), dtype=dtype)
    return p


def _capacity(T: int, k: int, E: int, factor: float) -> int:
    c = int(T * k * factor / E) + 1
    return max(8, -(-c // 8) * 8)             # round up to 8


def _dispatch_group(xt, p, cfg, C):
    """Dispatch/FFN/combine for one token group. xt: (T, D) → (T, D).

    Group-local: positions come from a cumsum over THIS group's tokens
    only, so under vmap each data shard routes independently — no global
    cumsum serializing across shards (which made GSPMD replicate the full
    token tensor: 37–55 GiB on dbrx train, §Perf iteration)."""
    T, D = xt.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (xt.astype(jnp.float32) @ p["router"])            # (T, E)
    top_vals, top_idx = jax.lax.top_k(logits, K)               # (T, K)
    weights = jax.nn.softmax(top_vals, axis=-1)                # (T, K)

    e_flat = top_idx.reshape(-1)                               # (T·K,)
    w_flat = weights.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T), K)

    # position of each (token, k) inside its expert's buffer
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)            # (T·K, E)
    pos = (jnp.cumsum(oh, axis=0) - 1)
    pos = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, e_flat * C + pos, E * C)            # drop → OOB

    # gather tokens into (E·C, D) expert buffers
    tok_of_slot = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        tok_flat, mode="drop")
    valid = jnp.zeros((E * C + 1,), bool).at[slot].set(keep, mode="drop")
    tok_of_slot = tok_of_slot[:-1]
    valid = valid[:-1]
    xe = xt[tok_of_slot] * valid[:, None].astype(xt.dtype)
    # pin expert buffers to the EP layout (E over "model") — without this
    # GSPMD replicates the dispatch buffers (granite-moe: +25 GiB)
    xe = ctx.constrain(xe.reshape(E, C, D), "model", None, None)

    # batched expert FFN (E sharded over "model" by the param specs)
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * up
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * up
    else:
        h = jnp.square(jax.nn.relu(up))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ye = ctx.constrain(ye, "model", None, None).reshape(E * C, D)

    # combine: weighted scatter-add back to tokens
    w_of_slot = jnp.zeros((E * C + 1,), w_flat.dtype).at[slot].set(
        w_flat, mode="drop")[:-1]
    contrib = ye * (w_of_slot * valid).astype(ye.dtype)[:, None]
    return jnp.zeros((T, D), ye.dtype).at[tok_of_slot].add(
        contrib, mode="drop")


def moe_apply(p, x, cfg, groups: int | None = None):
    """x: (B, S, D) → (B, S, D).

    Tokens route in ``groups`` independent batches, each with its own
    capacity. Auto policy (measured, §Perf iterations 8/9): per-batch-row
    grouping when the expert count divides the model axis (dbrx 16e:
    shard-local routing, −50% collectives), global dispatch otherwise
    (granite-moe 40e: per-group capacity padding on an uneven EP layout
    costs more than the global cumsum saves)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if groups is None:
        tp = ctx.axis_size("model")
        groups = B if (tp and E % tp == 0) else 1
    G = min(groups, B)
    while B % G:
        G -= 1
    Tg = B * S // G
    C = _capacity(Tg, K, E, cfg.capacity_factor)
    xg = x.reshape(G, Tg, D)
    spec = ("batch", None, None) if G > 1 else (None, "batch", None)
    xg = ctx.constrain(xg, *spec)
    out = jax.vmap(lambda t: _dispatch_group(t, p, cfg, C))(xg)
    out = ctx.constrain(out, *spec)
    return out.reshape(B, S, D).astype(x.dtype)


def aux_load_balance_loss(router_logits, top_idx, E: int):
    """Switch-style auxiliary loss (fraction·probability per expert)."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_idx[..., 0], E), axis=0)
    prob = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * prob)
