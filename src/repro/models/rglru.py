"""RG-LRU recurrent blocks (RecurrentGemma) — gated linear recurrence.

    r_t = σ(W_r x_t)            (recurrence gate)
    i_t = σ(W_i x_t)            (input gate)
    a_t = exp(-c · softplus(Λ) ⊙ r_t)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Train/prefill uses `jax.lax.associative_scan` (the recurrence is an affine
map composition → O(S log S) parallel depth — the sub-quadratic property
that makes long_500k runnable for this family).  Decode is the single-step
recurrence with a carried state.

Note (DESIGN.md §5): this gated recurrence is input-dependent (IIR with
time-varying coefficients), so the paper's FFT convolution does NOT apply to
it — FFTB integration for hybrids is limited to the conv path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import causal_conv1d, dense_init

_C = 8.0     # RecurrentGemma's fixed temperature


def rglru_init(key, cfg, dtype):
    D = cfg.d_model
    R = cfg.d_rnn or D
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], (D, R), dtype=dtype),       # input branch
        "w_gate_in": dense_init(ks[1], (D, R), dtype=dtype),  # gating branch
        "conv_w": dense_init(ks[2], (cfg.conv_kernel, R), scale=0.5,
                             dtype=dtype),
        "w_r": dense_init(ks[3], (R, R), dtype=dtype),
        "w_i": dense_init(ks[4], (R, R), dtype=dtype),
        "lam": jnp.full((R,), 0.7, jnp.float32),             # Λ init
        "w_out": dense_init(ks[5], (R, D), dtype=dtype),
    }


def _gates(p, u):
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u.astype(jnp.float32))
    return a, b


def rglru_block(p, x, cfg, *, state=None):
    """One recurrent block. x: (B,S,D) → (B,S,D); state carries
    {"conv": (B,K-1,R), "h": (B,R)} for decode."""
    B, S, D = x.shape
    u = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_gate_in"])
    u, conv_cache = causal_conv1d(
        u, p["conv_w"], None if state is None else state["conv"])

    a, b = _gates(p, u)                                   # (B,S,R) f32
    if state is not None and S == 1:
        h_prev = state["h"]
        h = a[:, 0] * h_prev + b[:, 0]
        hs = h[:, None]
        new_state = {"conv": conv_cache, "h": h}
    else:
        # h_t = a_t h_{t-1} + b_t  ⇒ compose (a, b) affine maps
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a2 * a1, a2 * b1 + b2
        if state is not None:
            b = b.at[:, 0].add(a[:, 0] * state["h"])
        a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = b_s
        new_state = None if state is None else \
            {"conv": conv_cache, "h": hs[:, -1]}
    y = (hs * gate.astype(jnp.float32)).astype(x.dtype)
    return y @ p["w_out"], new_state


def rglru_init_state(cfg, batch: int, dtype=jnp.float32):
    R = cfg.d_rnn or cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.conv_kernel - 1, R), dtype),
            "h": jnp.zeros((batch, R), jnp.float32)}
