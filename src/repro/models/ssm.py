"""Mamba-2 (SSD — state-space duality) blocks, chunked matmul form.

The SSD dual form computes attention-free sequence mixing as chunk-local
quadratic matmuls plus a linear inter-chunk state recurrence — exactly the
MXU-friendly decomposition. The depthwise temporal conv optionally routes
through FFTB's fft_conv (`conv_impl="fft"`), the paper-technique
integration point for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import flags


def _scan(f, init, xs, **kw):
    kw.setdefault("unroll", True if flags.scan_unroll() else 1)
    return jax.lax.scan(f, init, xs, **kw)

from .layers import causal_conv1d, dense_init, fft_causal_conv1d, rms_norm


def ssm_init(key, cfg, dtype):
    D = cfg.d_model
    din = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_nheads
    conv_dim = din + 2 * N                      # conv over (x, B, C)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * din + 2 * N + H), dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_dim),
                             scale=0.5, dtype=dtype),
        "out_proj": dense_init(ks[2], (din, D), dtype=dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.zeros((din,), jnp.float32),
    }


def _split_proj(z, cfg):
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    zx, gate, Bm, Cm, dt = jnp.split(
        z, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    return zx, gate, Bm, Cm, dt


def _segsum(dA):
    """(..., Q) → (..., Q, Q) lower-triangular cumulative sums:
    out[i, j] = sum_{j < k <= i} dA[k]."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """SSD sequence mixing.

    xh: (B,S,H,P) inputs, dt: (B,S,H) positive step sizes, A: (H,) < 0,
    Bm/Cm: (B,S,N) shared across heads (ngroups=1).  Returns (B,S,H,P).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q
    xc = xh.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    dA = dtc * A                                             # (B,nc,Q,H)

    # ---- intra-chunk (quadratic within Q) ----
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)           # (B,nc,Q,Q)
    M = scores[:, :, None] * Lmat                            # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtc, xc)

    # ---- chunk states ----
    dA_cum = jnp.cumsum(dA, axis=2)                          # (B,nc,Q,H)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)    # (B,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchnp",
                        Bc, dtc * decay_to_end, xc)          # (B,nc,H,N,P)

    # ---- inter-chunk recurrence over nc ----
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (B,nc,H)

    def scan_fn(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    init = jnp.zeros((Bsz, H, N, P), states.dtype)
    _, s_in = _scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_in = s_in.transpose(1, 0, 2, 3, 4)                     # (B,nc,H,N,P)

    decay_from_start = jnp.exp(dA_cum)                       # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc, decay_from_start, s_in)
    return (y_intra + y_inter).reshape(Bsz, S, H, P)


def ssm_block(p, x, cfg, *, state=None):
    """One Mamba-2 block. x: (B,S,D).

    state: None (train/prefill from scratch) or dict with "conv" (B,K-1,conv_dim)
    and "ssm" (B,H,N,P) for single-step decode (S == 1).
    Returns (y, new_state).
    """
    B, S, D = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    z = x @ p["in_proj"]
    zx, gate, Bm, Cm, dt = _split_proj(z, cfg)
    conv_in = jnp.concatenate([zx, Bm, Cm], axis=-1)

    decode = state is not None and S == 1
    conv = fft_causal_conv1d if cfg.conv_impl == "fft" and not decode \
        else causal_conv1d
    conv_out, conv_cache = conv(
        conv_in, p["conv_w"], None if state is None else state["conv"])
    conv_out = jax.nn.silu(conv_out)
    zx, Bm, Cm = jnp.split(conv_out, [din, din + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)
    xh = zx.reshape(B, S, H, P)

    if decode:
        s_prev = state["ssm"]                                    # (B,H,N,P)
        dA = jnp.exp(dt[:, 0] * A)                               # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                         dt[:, 0], xh[:, 0].astype(jnp.float32))
        s_new = s_prev * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), s_new)
        y = y[:, None] + p["D_skip"][None, None, :, None] * xh
        new_state = {"conv": conv_cache, "ssm": s_new}
    else:
        y = ssd_chunked(xh.astype(jnp.float32), dt, A,
                        Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                        cfg.ssm_chunk)
        y = y + p["D_skip"][None, None, :, None] * xh.astype(jnp.float32)
        if state is not None:       # prefill: also emit final state
            # recompute final state cheaply from the chunked pass
            new_state = {"conv": conv_cache,
                         "ssm": _final_state(xh, dt, A, Bm, Cm)}
        else:
            new_state = None
    y = y.reshape(B, S, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(gate), p["norm_scale"], cfg.norm_eps)
    return y @ p["out_proj"], new_state


def _final_state(xh, dt, A, Bm, Cm):
    """Final SSM state after a full sequence (for prefill → decode)."""
    dA = dt * A                                              # (B,S,H)
    dA_cum = jnp.cumsum(dA, axis=1)
    decay_to_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)       # (B,S,H)
    return jnp.einsum("bsn,bsh,bshp->bhnp",
                      Bm.astype(jnp.float32), dt * decay_to_end,
                      xh.astype(jnp.float32))


def ssm_init_state(cfg, batch: int, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_state,
                          cfg.ssm_headdim), jnp.float32),
    }
