"""Deterministic, restart-safe, sharded data pipeline.

Every batch is a pure function of (seed, step, shard) — no iterator state
exists anywhere, so preemption/restart resumes mid-epoch exactly, straggler
shards can be re-assigned to backup hosts deterministically, and elastic
re-scaling just changes the (shard, n_shards) factorization.

Two sources:
  * synthetic  — hashed-counter tokens (bench/dry-run/CI),
  * memmap     — a flat token file (np.memmap), strided like MaxText-style
                 deterministic grain indexing.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    path: str = ""


def _philox(seed: np.uint64, counter: np.ndarray) -> np.ndarray:
    """Cheap stateless hash (splitmix64) — enough for synthetic tokens."""
    x = (counter + np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15))
    x = x.astype(np.uint64)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


class Pipeline:
    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1):
        if cfg.global_batch % n_shards:
            raise ValueError("global_batch must divide over shards")
        self.cfg = cfg
        self.shard, self.n_shards = shard, n_shards
        self.local_batch = cfg.global_batch // n_shards
        self._mm = None
        if cfg.source == "memmap":
            self._mm = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of step → {"tokens", "labels"} for this shard."""
        cfg = self.cfg
        B, S = self.local_batch, cfg.seq
        row0 = (step * cfg.global_batch + self.shard * B)
        if self._mm is None:
            counters = (np.uint64(row0) * np.uint64(S + 1)
                        + np.arange(B * (S + 1), dtype=np.uint64).reshape(
                            B, S + 1))
            toks = (_philox(np.uint64(cfg.seed), counters)
                    % np.uint64(cfg.vocab)).astype(np.int32)
        else:
            n = self._mm.shape[0] - (S + 1)
            idx = (_philox(np.uint64(cfg.seed),
                           row0 + np.arange(B, dtype=np.uint64))
                   % np.uint64(max(n, 1))).astype(np.int64)
            toks = np.stack([self._mm[i:i + S + 1] for i in idx])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def reassign(self, dead_shard: int, step: int) -> dict[str, np.ndarray]:
        """Straggler/failure mitigation: any host can deterministically
        recompute another shard's batch (backup-worker pattern)."""
        backup = Pipeline(self.cfg, dead_shard, self.n_shards)
        return backup.batch_at(step)
