"""repro.dft — the plane-wave SCF workload the FFT framework was built for.

A self-consistent Kohn-Sham-like calculation run entirely on FFTB plans:

  * ``basis``       per-k-point cut-off spheres (a *batch of different
                    spheres*), G-vector / |G+k|² bookkeeping, plan retrieval
                    through the process-global ``PlanCache``
  * ``hamiltonian`` kinetic on packed coefficients + local-potential apply
                    via band-batched sphere→cube→sphere round-trips
  * ``density``     ρ(r) = Σ_{k,b} w_k f_b |ψ_kb(r)|², accumulated sharded
  * ``hartree``     Poisson solve in G-space on the full-cube plan pair
  * ``potentials``  Gaussian-well external potential + LDA-style exchange
  * ``scf``         the mixing-driven SCF driver (linear + Anderson/Pulay)

Quickstart::

    from repro.dft import SCFConfig, run_scf
    res = run_scf(SCFConfig(n=16, nbands=4,
                            kpts=((0, 0, 0), (0.5, 0.5, 0.5))))
    print(res.energy, res.converged, res.cache_stats)
"""

from .basis import CUBE_SPEC, PW_SPEC, PlaneWaveBasis, StackedBandTables
from .density import density_from_orbitals, density_from_stacked
from .hamiltonian import (apply_hamiltonian, apply_hamiltonian_padded,
                          apply_hamiltonian_pipelined,
                          apply_hamiltonian_stacked, update_bands,
                          update_bands_all_k, update_bands_stacked)
from .hartree import HartreeSolver, coulomb_kernel
from .potentials import gaussian_wells, lda_exchange
from .scf import (AndersonMixer, LinearMixer, SCFConfig, SCFResult, run_scf,
                  total_energy, total_energy_stacked)

__all__ = [
    "PlaneWaveBasis", "StackedBandTables", "PW_SPEC", "CUBE_SPEC",
    "density_from_orbitals", "density_from_stacked",
    "apply_hamiltonian", "apply_hamiltonian_padded",
    "apply_hamiltonian_pipelined", "apply_hamiltonian_stacked",
    "update_bands", "update_bands_all_k", "update_bands_stacked",
    "HartreeSolver", "coulomb_kernel", "gaussian_wells", "lda_exchange",
    "SCFConfig", "SCFResult", "run_scf", "total_energy",
    "total_energy_stacked", "LinearMixer", "AndersonMixer",
]
