"""Electron density from sphere-packed orbitals.

    ρ(r) = (n³/ΔV) Σ_k w_k Σ_b f_kb |ψ_kb(r)|²

with ψ = ifft(c) the *unnormalized* inverse transform of unit-norm packed
coefficients (Σ_G |c_G|² = 1 ⇒ Σ_r |ψ_r|² = 1/n³), so the prefactor makes
each occupied orbital integrate to one electron: Σ_r ρ ΔV = Σ w·f.

The per-k inverse plans come from the plan cache (one batched transform per
k-point, bands batched); the accumulation runs on the real-space cubes as
they come out of the plans — z-sharded on a multi-device grid — so the sum
over bands and k-points never gathers the mesh.

On a (batch × fft) 2D grid where ``nk`` divides the batch-axis size
(``basis.stacks_k``), all k-points' padded coefficients are stacked into
one ragged batch of nk·nbands and pushed through a *single* staged-padding
transform (``basis.stacked_hamiltonian_plans()`` — the same pair the
stacked H apply uses): the batch axes then shard k-points and bands
jointly, and nk per-k dispatches collapse into one.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp


def density_from_stacked(basis, c_pad, occ, seg: int = 0) -> jnp.ndarray:
    """Segment ``seg``'s density contribution from its padded
    (nk_seg, nbands, pad_width) coefficient stack.

    One nk_seg·nbands-batched transform; k and bands shard the batch
    axes.  Rides the same ragged ``StackedPlaneWaveFFT`` pair as the
    stacked Hamiltonian apply (padded per-k pack tables, shared d³→n³
    plan), so the stacked SCF path never needs the per-k sphere plans at
    all.  ``occ`` is the *full* (nk, nbands) table — the segment's rows
    are selected here, weights included, so summing the per-segment
    contributions (each carries the n³/ΔV prefactor, the sum is linear)
    gives exactly ρ.  With the default single segment this is the whole
    density.  Padded lanes never reach the cube (the unpack scatter
    routes them to the dump slot), so they contribute nothing to ρ.
    Traceable — the jitted SCF step runs it under ``jax.jit``; ``occ``
    must be a trace-time constant (numpy).
    """
    inv, _ = basis.stacked_hamiltonian_plans(seg)
    nks, nb, npm = c_pad.shape
    psi = inv(inv.unpack(c_pad.reshape(nks * nb, npm)))
    idx = list(basis.segments[seg])
    w = (basis.weights[idx, None] * np.asarray(occ, np.float64)[idx]
         ).reshape(-1).astype(np.float32)
    rho = jnp.tensordot(jnp.asarray(w), jnp.abs(psi) ** 2, axes=(0, 0))
    return rho * jnp.float32(basis.n ** 3 / basis.dv)


def _density_stacked(basis, coeffs, occ) -> jnp.ndarray:
    """Per-k blocks → stacked-batch density, one batch per segment."""
    rho = None
    for s, seg in enumerate(basis.segments):
        inv, _ = basis.stacked_hamiltonian_plans(s)
        c_pad = inv.stack([coeffs[ik] for ik in seg]).reshape(
            len(seg), basis.nbands, inv.npacked_max)
        part = density_from_stacked(basis, c_pad, occ, seg=s)
        rho = part if rho is None else rho + part
    return rho


def density_from_orbitals(basis, coeffs, occ) -> jnp.ndarray:
    """ρ(r) on the n³ cube (f32) from per-k packed coefficient blocks.

    coeffs: list of (nbands, npacked_k) complex blocks, one per k-point
    occ:    (nk, nbands) occupation numbers f_kb
    """
    occ = np.asarray(occ, np.float64)
    if occ.shape != (basis.nk, basis.nbands):
        raise ValueError(
            f"occ shape {occ.shape} != (nk, nbands) = "
            f"({basis.nk}, {basis.nbands})")
    if getattr(basis, "stacks_k", False):
        return _density_stacked(basis, coeffs, occ)   # prefactor included
    rho = jnp.zeros((basis.n,) * 3, jnp.float32)
    for ik, c in enumerate(coeffs):
        inv, _ = basis.plans_for_k(ik)
        psi = inv(inv.unpack(c))              # (nb, n, n, n) sharded
        f = jnp.asarray((basis.weights[ik] * occ[ik]).astype(np.float32))
        rho = rho + jnp.tensordot(f, jnp.abs(psi) ** 2, axes=(0, 0))
    return rho * jnp.float32(basis.n ** 3 / basis.dv)


def electron_count(basis, rho) -> float:
    """∫ ρ dr — sanity invariant (should equal Σ_k w_k Σ_b f_kb)."""
    return float(jnp.sum(rho) * basis.dv)
