"""Local potentials: Gaussian wells (external) + LDA-style exchange.

The external potential is a sum of attractive Gaussian wells — smooth
pseudopotential-like cores without structure-factor machinery.  The
density-functional term is Slater exchange (the LDA X-only functional),
enough to make the SCF loop genuinely nonlinear in ρ.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

#: Slater exchange constant C_x = (3/4)(3/π)^{1/3}
_CX = 0.75 * (3.0 / np.pi) ** (1.0 / 3.0)


def gaussian_wells(n: int, centers=None, depth: float = 4.0,
                   width: float | None = None) -> np.ndarray:
    """Sum of attractive Gaussians on the n³ grid (f32, numpy).

    Defaults mirror the original mini-app: two wells on the cube diagonal
    at 0.3·n and 0.7·n, width n/16.
    """
    if centers is None:
        centers = [(n * 0.3,) * 3, (n * 0.7,) * 3]
    if width is None:
        width = n / 16.0
    xs = np.stack(np.meshgrid(*[np.arange(n)] * 3, indexing="ij"), -1)
    v = np.zeros((n, n, n), np.float32)
    for c in centers:
        v -= depth * np.exp(-((xs - np.asarray(c)) ** 2).sum(-1)
                            / (2 * width ** 2)).astype(np.float32)
    return v


def lda_exchange(rho):
    """Slater exchange: energy density e_x(r) and potential v_x(r).

    e_x = −C_x ρ^{4/3} (energy per volume; integrate with ΔV for E_x),
    v_x = δE_x/δρ = −(4/3) C_x ρ^{1/3}.  ρ is clipped at 0 — it is a sum
    of |ψ|² terms, so negatives are only mixing artifacts.
    """
    r = jnp.maximum(rho, 0.0)
    r13 = jnp.cbrt(r)
    e_x = -_CX * r13 * r
    v_x = -(4.0 / 3.0) * _CX * r13
    return e_x, v_x
