"""Kohn-Sham Hamiltonian apply + band updates — per k-point or k-stacked.

H is applied in the packed sphere basis:

    (H c)_G = ½|G+k|² c_G  +  pack( fft( v_eff(r) · ifft(unpack(c)) ) )

— kinetic is diagonal on packed coefficients, the local potential is a
batched sphere→cube→sphere round-trip (inverse plan, pointwise multiply,
derived forward plan).  Bands ride the plans' batch dimension, so one H
apply per k-point is two batched distributed transforms regardless of the
band count — the matrix-matrix form the paper's batching argument is about.
When the basis stacks k-points (``basis.stacks_k``) the argument extends
across k: :func:`apply_hamiltonian_stacked` pushes *all* nk·nbands
orbitals through one ragged padded batch, so the whole sweep is two
distributed transforms regardless of nk as well.

The band update is preconditioned all-band descent in its locally-optimal
form (LOBPCG without the history block): each step does a Rayleigh-Ritz
solve in the 2·nb-dimensional span of the current bands and their
preconditioned residuals, which picks the optimal step length per band
automatically.  The preconditioner is the Teter-style kinetic damping
1/(1 + ½|G+k|²).
"""
from __future__ import annotations

import jax.numpy as jnp


def _replicated(basis, x):
    """Pin an eager coefficient block onto the basis mesh, replicated.

    The band update mixes shard_map outputs (packed H·c blocks, sharded
    over the batch axes) with replicated/single-device blocks (QR and
    Rayleigh-Ritz outputs) in eager concatenates and matmuls — exactly
    the mixed-placement situation ``ProcGrid.replicate`` exists for
    (reported eigenvalues came out doubled on a 2×2 grid before every
    block was pinned; the eigenvectors survived only because a uniform
    scaling has the same eigenbasis).  No-op on a 1-device grid, so
    results there are bitwise unchanged.
    """
    return basis.grid.replicate(x)


def apply_hamiltonian(basis, ik: int, c, v_eff):
    """H·c for one k-point block c of shape (nbands, npacked_k).

    ``v_eff`` is the real (n, n, n) effective local potential.  Plans are
    fetched through the plan cache on every call — after the first SCF
    iteration these are all hits.
    """
    inv, fwd = basis.plans_for_k(ik)
    kin = basis.kinetic(ik)
    psi = inv(inv.unpack(c))                  # sphere → real space, batched
    vpsi = fwd(psi * v_eff)                   # apply V, truncate back
    return kin[None, :] * c + inv.pack(vpsi)


def apply_hamiltonian_pipelined(basis, blocks, v_eff):
    """H·c for *all* k-points, double-buffering the sphere→cube transforms.

    The serial loop alternates "all_to_all-heavy inverse transform" and
    "compute-heavy cube-space potential apply" per k-point, leaving the
    interconnect idle during the apply.  Here k-point ``ik+1``'s inverse
    transform (its comm) is dispatched *before* ``ik``'s potential apply,
    so on an asynchronous backend the next k's all_to_alls are in flight
    while the current k's cube multiply runs — the ROADMAP "pipeline
    k-point transforms" item.  Per-k operations and their order are
    identical to :func:`apply_hamiltonian`, so results match the serial
    path bit-for-bit; only the dispatch interleaving differs.

    ``blocks``: list of (nbands, npacked_k) coefficient blocks, one per k.
    Returns the list of H·c blocks in k order.
    """
    nk = len(blocks)
    if nk == 0:
        return []
    plans = [basis.plans_for_k(ik) for ik in range(nk)]
    inv0 = plans[0][0]
    psi = inv0(inv0.unpack(blocks[0]))        # prologue: k=0 in flight
    out = []
    for ik in range(nk):
        psi_next = None
        if ik + 1 < nk:                       # issue k+1's comm first …
            inv_n = plans[ik + 1][0]
            psi_next = inv_n(inv_n.unpack(blocks[ik + 1]))
        inv, fwd = plans[ik]                  # … then apply V for k
        vpsi = fwd(psi * v_eff)
        out.append(basis.kinetic(ik)[None, :] * blocks[ik]
                   + inv.pack(vpsi))
        psi = psi_next
    return out


def apply_hamiltonian_stacked(basis, blocks, v_eff):
    """H·c for *all* k-points in one ragged stacked batch.

    The pipelined path still dispatches one sphere→cube→sphere round trip
    per k-point; here every k-point's bands ride a single
    ``(nk·nbands, npacked_max)`` padded batch through the basis's
    ``StackedPlaneWaveFFT`` pair: **one** batched inverse transform, one
    cube-space ``v_eff`` multiply, one batched forward — two distributed
    transforms per H sweep regardless of nk and nbands.  Raggedness
    (distinct ``npacked_k``) is absorbed by the padded pack tables, whose
    dump/zero slots keep padded lanes inert; the kinetic diagonal is
    applied per k on the unpadded blocks.  Per-orbital math is identical
    to :func:`apply_hamiltonian` — same rectangular DFT stages, same
    pack/unpack values — so stacked ≡ pipelined ≡ serial per k.

    ``blocks``: list of (nbands, npacked_k) coefficient blocks, one per k.
    Returns the list of H·c blocks in k order.
    """
    nk = len(blocks)
    if nk == 0:
        return []
    inv, fwd = basis.stacked_hamiltonian_plans()
    psi = inv(inv.unpack(inv.stack(blocks)))  # every k and band at once
    vpsi = fwd(psi * v_eff)                   # apply V, truncate back
    vc = inv.split(inv.pack(vpsi))
    return [basis.kinetic(ik)[None, :] * blocks[ik] + vc[ik]
            for ik in range(nk)]


def orthonormalize(c):
    """QR re-orthonormalization; bands are rows of c."""
    q, r = jnp.linalg.qr(c.T)
    # fix the phase so the update is continuous across iterations
    ph = jnp.sign(jnp.real(jnp.diagonal(r)) + 1e-30)
    return (q * ph[None, :]).T


def _project_out(d, c):
    """Remove the span of rows of ``c`` from rows of ``d``."""
    return d - (jnp.conj(c) @ d.T).T @ c


def update_bands(basis, ik: int, c, v_eff, *, steps: int = 3):
    """Locally-optimal preconditioned band update for k-point ``ik``.

    Per step: residuals r_b = (H − λ_b)c_b, preconditioned and
    orthonormalized against the bands, then a Rayleigh-Ritz solve in
    span{c, P r} keeps the lowest ``nbands`` vectors.  Two batched H
    applies per step.

    Returns (rotated coefficients, eigenvalues ascending, n_h_applies).
    """
    kin = basis.kinetic(ik)
    pre = (1.0 / (1.0 + kin))[None, :]
    napply = 0
    eps = None
    c = _replicated(basis, c)
    for _ in range(steps):
        hc = _replicated(basis, apply_hamiltonian(basis, ik, c, v_eff))
        napply += 1
        d = _replicated(basis, _descent_direction(c, hc, pre))
        hd = _replicated(basis, apply_hamiltonian(basis, ik, d, v_eff))
        napply += 1
        c, eps = _rayleigh_ritz(c, d, hc, hd)
    return c, eps, napply


def _descent_direction(c, hc, pre):
    """Preconditioned residual block, orthonormalized against the bands."""
    lam = jnp.sum(jnp.conj(c) * hc, axis=1).real
    grad = hc - lam[:, None] * c
    return orthonormalize(_project_out(pre * grad, c))


def _rayleigh_ritz(c, d, hc, hd):
    """Lowest-nb Ritz vectors of span{c, d}; returns (c', eps ascending)."""
    nb = c.shape[0]
    basis_block = jnp.concatenate([c, d], axis=0)            # (2nb, np)
    h_block = jnp.concatenate([hc, hd], axis=0)
    hmat = jnp.conj(basis_block) @ h_block.T                 # ⟨b_i|H|b_j⟩
    eps, vecs = jnp.linalg.eigh(0.5 * (hmat + jnp.conj(hmat).T))
    return orthonormalize(vecs[:, :nb].T @ basis_block), eps[:nb]


def update_bands_all_k(basis, coeffs, v_eff, *, steps: int = 3,
                       stacked: bool | None = None):
    """All-k locally-optimal band update — stacked or pipelined H sweeps.

    The per-k math is :func:`update_bands` exactly — same preconditioner,
    same Rayleigh-Ritz step, same op order within each k — but the loop
    nest is inverted (steps outer, k inner) so each step's two H-apply
    sweeps cover every k-point at once.  ``stacked=None`` (the default)
    routes each sweep through :func:`apply_hamiltonian_stacked` when
    ``basis.stacks_k`` — one ragged nk·nbands batch, two distributed
    transforms per sweep — and falls back to
    :func:`apply_hamiltonian_pipelined` (k+1's sphere→cube all_to_alls
    dispatched before k's potential apply) otherwise; pass True/False to
    force a path, e.g. to use the pipelined loop as the equivalence
    oracle.  Because no arithmetic crosses k-points, both routes match
    running ``update_bands`` serially per k.

    Returns (new coefficient blocks, eigenvalues list [(nbands,)] per k,
    H sweeps executed — each sweep is one H apply per k-point).
    """
    nk = len(coeffs)
    if stacked is None:
        stacked = bool(getattr(basis, "stacks_k", False))
    sweep = apply_hamiltonian_stacked if stacked \
        else apply_hamiltonian_pipelined
    cs = [_replicated(basis, c) for c in coeffs]
    pres = [(1.0 / (1.0 + basis.kinetic(ik)))[None, :] for ik in range(nk)]
    eps_out = [None] * nk
    nsweep = 0
    for _ in range(steps):
        hcs = [_replicated(basis, hc) for hc in sweep(basis, cs, v_eff)]
        nsweep += 1
        ds = [_replicated(basis,
                          _descent_direction(cs[ik], hcs[ik], pres[ik]))
              for ik in range(nk)]
        hds = [_replicated(basis, hd) for hd in sweep(basis, ds, v_eff)]
        nsweep += 1
        for ik in range(nk):
            cs[ik], eps_out[ik] = _rayleigh_ritz(cs[ik], ds[ik],
                                                 hcs[ik], hds[ik])
    return cs, eps_out, nsweep
