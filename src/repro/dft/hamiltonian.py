"""Kohn-Sham Hamiltonian apply + band updates, per k-point.

H is applied in the packed sphere basis:

    (H c)_G = ½|G+k|² c_G  +  pack( fft( v_eff(r) · ifft(unpack(c)) ) )

— kinetic is diagonal on packed coefficients, the local potential is a
batched sphere→cube→sphere round-trip (inverse plan, pointwise multiply,
derived forward plan).  Bands ride the plans' batch dimension, so one H
apply per k-point is two batched distributed transforms regardless of the
band count — the matrix-matrix form the paper's batching argument is about.

The band update is preconditioned all-band descent in its locally-optimal
form (LOBPCG without the history block): each step does a Rayleigh-Ritz
solve in the 2·nb-dimensional span of the current bands and their
preconditioned residuals, which picks the optimal step length per band
automatically.  The preconditioner is the Teter-style kinetic damping
1/(1 + ½|G+k|²).
"""
from __future__ import annotations

import jax.numpy as jnp


def apply_hamiltonian(basis, ik: int, c, v_eff):
    """H·c for one k-point block c of shape (nbands, npacked_k).

    ``v_eff`` is the real (n, n, n) effective local potential.  Plans are
    fetched through the plan cache on every call — after the first SCF
    iteration these are all hits.
    """
    inv, fwd = basis.plans_for_k(ik)
    kin = basis.kinetic(ik)
    psi = inv(inv.unpack(c))                  # sphere → real space, batched
    vpsi = fwd(psi * v_eff)                   # apply V, truncate back
    return kin[None, :] * c + inv.pack(vpsi)


def apply_hamiltonian_pipelined(basis, blocks, v_eff):
    """H·c for *all* k-points, double-buffering the sphere→cube transforms.

    The serial loop alternates "all_to_all-heavy inverse transform" and
    "compute-heavy cube-space potential apply" per k-point, leaving the
    interconnect idle during the apply.  Here k-point ``ik+1``'s inverse
    transform (its comm) is dispatched *before* ``ik``'s potential apply,
    so on an asynchronous backend the next k's all_to_alls are in flight
    while the current k's cube multiply runs — the ROADMAP "pipeline
    k-point transforms" item.  Per-k operations and their order are
    identical to :func:`apply_hamiltonian`, so results match the serial
    path bit-for-bit; only the dispatch interleaving differs.

    ``blocks``: list of (nbands, npacked_k) coefficient blocks, one per k.
    Returns the list of H·c blocks in k order.
    """
    nk = len(blocks)
    if nk == 0:
        return []
    plans = [basis.plans_for_k(ik) for ik in range(nk)]
    inv0 = plans[0][0]
    psi = inv0(inv0.unpack(blocks[0]))        # prologue: k=0 in flight
    out = []
    for ik in range(nk):
        psi_next = None
        if ik + 1 < nk:                       # issue k+1's comm first …
            inv_n = plans[ik + 1][0]
            psi_next = inv_n(inv_n.unpack(blocks[ik + 1]))
        inv, fwd = plans[ik]                  # … then apply V for k
        vpsi = fwd(psi * v_eff)
        out.append(basis.kinetic(ik)[None, :] * blocks[ik]
                   + inv.pack(vpsi))
        psi = psi_next
    return out


def orthonormalize(c):
    """QR re-orthonormalization; bands are rows of c."""
    q, r = jnp.linalg.qr(c.T)
    # fix the phase so the update is continuous across iterations
    ph = jnp.sign(jnp.real(jnp.diagonal(r)) + 1e-30)
    return (q * ph[None, :]).T


def _project_out(d, c):
    """Remove the span of rows of ``c`` from rows of ``d``."""
    return d - (jnp.conj(c) @ d.T).T @ c


def update_bands(basis, ik: int, c, v_eff, *, steps: int = 3):
    """Locally-optimal preconditioned band update for k-point ``ik``.

    Per step: residuals r_b = (H − λ_b)c_b, preconditioned and
    orthonormalized against the bands, then a Rayleigh-Ritz solve in
    span{c, P r} keeps the lowest ``nbands`` vectors.  Two batched H
    applies per step.

    Returns (rotated coefficients, eigenvalues ascending, n_h_applies).
    """
    kin = basis.kinetic(ik)
    pre = (1.0 / (1.0 + kin))[None, :]
    napply = 0
    eps = None
    for _ in range(steps):
        hc = apply_hamiltonian(basis, ik, c, v_eff)
        napply += 1
        d = _descent_direction(c, hc, pre)
        hd = apply_hamiltonian(basis, ik, d, v_eff)
        napply += 1
        c, eps = _rayleigh_ritz(c, d, hc, hd)
    return c, eps, napply


def _descent_direction(c, hc, pre):
    """Preconditioned residual block, orthonormalized against the bands."""
    lam = jnp.sum(jnp.conj(c) * hc, axis=1).real
    grad = hc - lam[:, None] * c
    return orthonormalize(_project_out(pre * grad, c))


def _rayleigh_ritz(c, d, hc, hd):
    """Lowest-nb Ritz vectors of span{c, d}; returns (c', eps ascending)."""
    nb = c.shape[0]
    basis_block = jnp.concatenate([c, d], axis=0)            # (2nb, np)
    h_block = jnp.concatenate([hc, hd], axis=0)
    hmat = jnp.conj(basis_block) @ h_block.T                 # ⟨b_i|H|b_j⟩
    eps, vecs = jnp.linalg.eigh(0.5 * (hmat + jnp.conj(hmat).T))
    return orthonormalize(vecs[:, :nb].T @ basis_block), eps[:nb]


def update_bands_all_k(basis, coeffs, v_eff, *, steps: int = 3):
    """Pipelined locally-optimal band update across *every* k-point.

    The per-k math is :func:`update_bands` exactly — same preconditioner,
    same Rayleigh-Ritz step, same op order within each k — but the loop
    nest is inverted (steps outer, k inner) so each step's two H-apply
    sweeps go through :func:`apply_hamiltonian_pipelined`: k+1's
    sphere→cube all_to_alls are dispatched before k's cube-space potential
    apply.  Because no arithmetic crosses k-points, the results are
    bitwise identical to running ``update_bands`` serially per k.

    Returns (new coefficient blocks, eigenvalues list [(nbands,)] per k,
    pipelined H sweeps executed — each sweep is one H apply per k-point).
    """
    nk = len(coeffs)
    cs = list(coeffs)
    pres = [(1.0 / (1.0 + basis.kinetic(ik)))[None, :] for ik in range(nk)]
    eps_out = [None] * nk
    nsweep = 0
    for _ in range(steps):
        hcs = apply_hamiltonian_pipelined(basis, cs, v_eff)
        nsweep += 1
        ds = [_descent_direction(cs[ik], hcs[ik], pres[ik])
              for ik in range(nk)]
        hds = apply_hamiltonian_pipelined(basis, ds, v_eff)
        nsweep += 1
        for ik in range(nk):
            cs[ik], eps_out[ik] = _rayleigh_ritz(cs[ik], ds[ik],
                                                 hcs[ik], hds[ik])
    return cs, eps_out, nsweep
