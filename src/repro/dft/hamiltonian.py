"""Kohn-Sham Hamiltonian apply + band updates, per k-point.

H is applied in the packed sphere basis:

    (H c)_G = ½|G+k|² c_G  +  pack( fft( v_eff(r) · ifft(unpack(c)) ) )

— kinetic is diagonal on packed coefficients, the local potential is a
batched sphere→cube→sphere round-trip (inverse plan, pointwise multiply,
derived forward plan).  Bands ride the plans' batch dimension, so one H
apply per k-point is two batched distributed transforms regardless of the
band count — the matrix-matrix form the paper's batching argument is about.

The band update is preconditioned all-band descent in its locally-optimal
form (LOBPCG without the history block): each step does a Rayleigh-Ritz
solve in the 2·nb-dimensional span of the current bands and their
preconditioned residuals, which picks the optimal step length per band
automatically.  The preconditioner is the Teter-style kinetic damping
1/(1 + ½|G+k|²).
"""
from __future__ import annotations

import jax.numpy as jnp


def apply_hamiltonian(basis, ik: int, c, v_eff):
    """H·c for one k-point block c of shape (nbands, npacked_k).

    ``v_eff`` is the real (n, n, n) effective local potential.  Plans are
    fetched through the plan cache on every call — after the first SCF
    iteration these are all hits.
    """
    inv, fwd = basis.plans_for_k(ik)
    kin = basis.kinetic(ik)
    psi = inv(inv.unpack(c))                  # sphere → real space, batched
    vpsi = fwd(psi * v_eff)                   # apply V, truncate back
    return kin[None, :] * c + inv.pack(vpsi)


def orthonormalize(c):
    """QR re-orthonormalization; bands are rows of c."""
    q, r = jnp.linalg.qr(c.T)
    # fix the phase so the update is continuous across iterations
    ph = jnp.sign(jnp.real(jnp.diagonal(r)) + 1e-30)
    return (q * ph[None, :]).T


def _project_out(d, c):
    """Remove the span of rows of ``c`` from rows of ``d``."""
    return d - (jnp.conj(c) @ d.T).T @ c


def update_bands(basis, ik: int, c, v_eff, *, steps: int = 3):
    """Locally-optimal preconditioned band update for k-point ``ik``.

    Per step: residuals r_b = (H − λ_b)c_b, preconditioned and
    orthonormalized against the bands, then a Rayleigh-Ritz solve in
    span{c, P r} keeps the lowest ``nbands`` vectors.  Two batched H
    applies per step.

    Returns (rotated coefficients, eigenvalues ascending, n_h_applies).
    """
    nb = c.shape[0]
    kin = basis.kinetic(ik)
    pre = (1.0 / (1.0 + kin))[None, :]
    napply = 0
    eps = None
    for _ in range(steps):
        hc = apply_hamiltonian(basis, ik, c, v_eff)
        napply += 1
        lam = jnp.sum(jnp.conj(c) * hc, axis=1).real
        grad = hc - lam[:, None] * c
        d = orthonormalize(_project_out(pre * grad, c))
        hd = apply_hamiltonian(basis, ik, d, v_eff)
        napply += 1
        basis_block = jnp.concatenate([c, d], axis=0)        # (2nb, np)
        h_block = jnp.concatenate([hc, hd], axis=0)
        hmat = jnp.conj(basis_block) @ h_block.T             # ⟨b_i|H|b_j⟩
        eps, vecs = jnp.linalg.eigh(0.5 * (hmat + jnp.conj(hmat).T))
        c = orthonormalize(vecs[:, :nb].T @ basis_block)
        eps = eps[:nb]
    return c, eps, napply
