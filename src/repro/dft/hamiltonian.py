"""Kohn-Sham Hamiltonian apply + band updates — per k-point or k-stacked.

H is applied in the packed sphere basis:

    (H c)_G = ½|G+k|² c_G  +  pack( fft( v_eff(r) · ifft(unpack(c)) ) )

— kinetic is diagonal on packed coefficients, the local potential is a
batched sphere→cube→sphere round-trip (inverse plan, pointwise multiply,
derived forward plan).  Bands ride the plans' batch dimension, so one H
apply per k-point is two batched distributed transforms regardless of the
band count — the matrix-matrix form the paper's batching argument is about.
When the basis stacks k-points (``basis.stacks_k``) the argument extends
across k: :func:`apply_hamiltonian_stacked` pushes *all* nk·nbands
orbitals through one ragged padded batch, so the whole sweep is two
distributed transforms regardless of nk as well.

The band update is preconditioned all-band descent in its locally-optimal
form (LOBPCG without the history block): each step does a Rayleigh-Ritz
solve in the 2·nb-dimensional span of the current bands and their
preconditioned residuals, which picks the optimal step length per band
automatically.  The preconditioner is the Teter-style kinetic damping
1/(1 + ½|G+k|²).

Two band-update engines share that math:

  * the **per-k** path (``update_bands`` / the pipelined loop inside
    ``update_bands_all_k``) runs the Gram builds, Rayleigh-Ritz solves
    and orthonormalizations k-point by k-point in eager Python — the
    fallback and equivalence oracle;
  * the **stacked** engine (:func:`update_bands_stacked`) runs them as
    batched einsums / batched ``eigh``/``qr`` over one padded
    ``(nk, nbands, npacked_max)`` coefficient array, with the kinetic
    and preconditioner served as dense per-k tables
    (``basis.stacked_band_tables()``).  Padded lanes hold exact zeros in
    coefficients, H·c blocks and tables alike, so they contribute exact
    zeros to every reduction and the two engines agree bitwise on CPU
    (asserted to 1e-10 in tests).  One sweep is **two** distributed
    transforms and **zero** per-k Python linalg calls, whatever nk is —
    ``PERK_LINALG_CALLS`` and ``FftPlan.executions`` instrument exactly
    that.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.obs.metrics import global_metrics
from repro.obs.trace import get_tracer

#: process-wide count of per-k eager linalg calls (descent-direction
#: builds and Rayleigh-Ritz solves dispatched for a single k-point) —
#: lets tests assert the stacked engine performs zero of them.
PERK_LINALG_CALLS = 0

global_metrics().register_probe(
    "dft", lambda: {"per_k_linalg_calls": PERK_LINALG_CALLS})


def _replicated(basis, x):
    """Pin an eager coefficient block onto the basis mesh, replicated.

    The band update mixes shard_map outputs (packed H·c blocks, sharded
    over the batch axes) with replicated/single-device blocks (QR and
    Rayleigh-Ritz outputs) in eager concatenates and matmuls — exactly
    the mixed-placement situation ``ProcGrid.replicate`` exists for
    (reported eigenvalues came out doubled on a 2×2 grid before every
    block was pinned; the eigenvectors survived only because a uniform
    scaling has the same eigenbasis).  No-op on a 1-device grid, so
    results there are bitwise unchanged.
    """
    return basis.grid.replicate(x)


def apply_hamiltonian(basis, ik: int, c, v_eff):
    """H·c for one k-point block c of shape (nbands, npacked_k).

    ``v_eff`` is the real (n, n, n) effective local potential.  Plans are
    fetched through the plan cache on every call — after the first SCF
    iteration these are all hits.
    """
    inv, fwd = basis.plans_for_k(ik)
    kin = basis.kinetic(ik)
    psi = inv(inv.unpack(c))                  # sphere → real space, batched
    vpsi = fwd(psi * v_eff)                   # apply V, truncate back
    return kin[None, :] * c + inv.pack(vpsi)


def apply_hamiltonian_pipelined(basis, blocks, v_eff):
    """H·c for *all* k-points, double-buffering the sphere→cube transforms.

    The serial loop alternates "all_to_all-heavy inverse transform" and
    "compute-heavy cube-space potential apply" per k-point, leaving the
    interconnect idle during the apply.  Here k-point ``ik+1``'s inverse
    transform (its comm) is dispatched *before* ``ik``'s potential apply,
    so on an asynchronous backend the next k's all_to_alls are in flight
    while the current k's cube multiply runs — the ROADMAP "pipeline
    k-point transforms" item.  Per-k operations and their order are
    identical to :func:`apply_hamiltonian`, so results match the serial
    path bit-for-bit; only the dispatch interleaving differs.

    ``blocks``: list of (nbands, npacked_k) coefficient blocks, one per k.
    Returns the list of H·c blocks in k order.
    """
    nk = len(blocks)
    if nk == 0:
        return []
    plans = [basis.plans_for_k(ik) for ik in range(nk)]
    inv0 = plans[0][0]
    psi = inv0(inv0.unpack(blocks[0]))        # prologue: k=0 in flight
    out = []
    for ik in range(nk):
        psi_next = None
        if ik + 1 < nk:                       # issue k+1's comm first …
            inv_n = plans[ik + 1][0]
            psi_next = inv_n(inv_n.unpack(blocks[ik + 1]))
        inv, fwd = plans[ik]                  # … then apply V for k
        vpsi = fwd(psi * v_eff)
        out.append(basis.kinetic(ik)[None, :] * blocks[ik]
                   + inv.pack(vpsi))
        psi = psi_next
    return out


def apply_hamiltonian_padded(basis, c_pad, v_eff, kin_pad=None,
                             seg: int = 0):
    """H·c on one segment's padded ``(nk_seg, nbands, pad_width)`` stack.

    The array-native core of the stacked route: one batched inverse
    transform, one cube-space ``v_eff`` multiply, one batched forward —
    two distributed transforms for every k-point and band at once — plus
    the dense padded kinetic diagonal (``basis.stacked_band_tables(seg)``)
    applied as a broadcast multiply.  Padded lanes stay exact zeros: the
    pack gather reads them from the zero slot and the kinetic table is
    zero there, so H·c is as inert on padding as c itself.  Traceable
    (the jitted SCF step runs it under ``jax.jit``).

    The sphere↔cube legs go through the plans' fused entry points
    (``unpack_transform`` / ``transform_pack``): with ``backend="pallas"``
    these route the unpack + first iDFT stage and the last DFT stage +
    pack gather through the fused sphere-pack kernels (no d³ cube ever
    materialized); on every other backend they fall back to the composed
    ``unpack``/plan/``pack`` calls, which are bitwise-identical — so this
    one code path serves both the oracle and the optimized route.
    """
    if kin_pad is None:
        kin_pad = basis.stacked_band_tables(seg).kinetic
    inv, fwd = basis.stacked_hamiltonian_plans(seg)
    nk, nb, npm = c_pad.shape
    psi = inv.unpack_transform(c_pad.reshape(nk * nb, npm))
    vc = fwd.transform_pack(psi * v_eff).reshape(nk, nb, npm)
    return kin_pad[:, None, :] * c_pad + vc


def apply_hamiltonian_stacked(basis, blocks, v_eff):
    """H·c for *all* k-points in ragged stacked batches, one per segment.

    The pipelined path still dispatches one sphere→cube→sphere round trip
    per k-point; here each segment's bands ride a single
    ``(nk_seg·nbands, pad_width)`` padded batch through the basis's
    ``StackedPlaneWaveFFT`` pair (:func:`apply_hamiltonian_padded`):
    two distributed transforms per H sweep per segment regardless of nk
    and nbands (one pair total with the default single segment).
    Raggedness (distinct ``npacked_k``) is absorbed by the padded pack
    tables, whose dump/zero slots keep padded lanes inert; the kinetic
    diagonal rides the dense padded table, which matches the per-k
    ladders bitwise on valid lanes.  Per-orbital math is identical to
    :func:`apply_hamiltonian` — same rectangular DFT stages, same
    pack/unpack values — so stacked ≡ pipelined ≡ serial per k.

    ``blocks``: list of (nbands, npacked_k) coefficient blocks, one per k.
    Returns the list of H·c blocks in k order.
    """
    if len(blocks) == 0:
        return []
    out = [None] * len(blocks)
    for s, seg in enumerate(basis.segments):
        inv, _ = basis.stacked_hamiltonian_plans(s)
        c_pad = inv.stack([blocks[ik] for ik in seg]).reshape(
            len(seg), inv.nbands, inv.npacked_max)
        hc = apply_hamiltonian_padded(basis, c_pad, v_eff, seg=s)
        hcs = inv.split(hc.reshape(len(seg) * inv.nbands, inv.npacked_max))
        for j, ik in enumerate(seg):
            out[ik] = hcs[j]
    return out


def orthonormalize(c):
    """QR re-orthonormalization; bands are rows of c."""
    q, r = jnp.linalg.qr(c.T)
    # fix the phase so the update is continuous across iterations
    ph = jnp.sign(jnp.real(jnp.diagonal(r)) + 1e-30)
    return (q * ph[None, :]).T


def _pad_lanes(x, npm: int):
    """Zero-pad the packed-coefficient axis of ``x`` to ``npm`` lanes.

    Both band-update engines contract their Gram/descent linalg over
    exactly ``npacked_max`` lanes — f32 GEMM reductions are *not*
    invariant under zero-padding the contraction length (the kernel's
    blocking changes with it), so running the per-k oracle over npk
    lanes and the stacked engine over npacked_max would leave an ~1e-5
    reduction-noise gap between mathematically identical results.
    Padding both to the same length makes the two engines execute
    identical kernels on identical operands: bitwise agreement, not
    approximate.
    """
    return jnp.pad(x, ((0, 0), (0, npm - x.shape[-1])))


def _padded_precond(basis, ik: int):
    """Per-k Teter damping row, zero-padded to the k's segment lane width.

    Valid lanes carry the same f32 ``1/(1 + kinetic)`` arithmetic as the
    stacked ``precond`` table row (bitwise), built locally so the per-k
    fallback never touches the band-tables cache entry — its plan-cache
    ledger stays purely per-k traffic.  Padding to ``pad_width(ik)``
    (``npacked_max`` with the default single segment) keeps the per-k
    oracle's contraction lengths equal to the stacked engine's.
    """
    pre = 1.0 / (1.0 + basis.kinetic(ik))
    return jnp.pad(pre, (0, basis.pad_width(ik) - pre.shape[0]))


def update_bands(basis, ik: int, c, v_eff, *, steps: int = 3):
    """Locally-optimal preconditioned band update for k-point ``ik``.

    Per step: residuals r_b = (H − λ_b)c_b, preconditioned and
    orthonormalized against the bands, then a Rayleigh-Ritz solve in
    span{c, P r} keeps the lowest ``nbands`` vectors.  Two batched H
    applies per step, riding the per-k sphere plans; the linalg runs as
    singleton-batch dispatches of the stacked kernels over lanes padded
    to the k's segment width (:func:`_pad_lanes`), so this serial oracle
    and the batched engine agree bit for bit.

    Returns (rotated coefficients, eigenvalues ascending, n_h_applies).
    """
    npm = basis.pad_width(ik)
    pre = _padded_precond(basis, ik)
    napply = 0
    eps = None
    c = _replicated(basis, c)
    for _ in range(steps):
        hc = _replicated(basis, apply_hamiltonian(basis, ik, c, v_eff))
        napply += 1
        d = _replicated(basis, _descent_direction(c, hc, pre, npm))
        hd = _replicated(basis, apply_hamiltonian(basis, ik, d, v_eff))
        napply += 1
        c, eps = _rayleigh_ritz(c, d, hc, hd, npm)
    return c, eps, napply


def _descent_direction(c, hc, pre, npm: int):
    """Per-k preconditioned residual block, orthogonal to the bands.

    A singleton-batch dispatch of :func:`_descent_direction_stacked`
    over npacked_max-padded operands — one per-k eager linalg call,
    counted by ``PERK_LINALG_CALLS``.  ``pre`` is the padded per-k
    damping row.  Returns the unpadded (nbands, npk) block.
    """
    global PERK_LINALG_CALLS
    PERK_LINALG_CALLS += 1
    npk = c.shape[-1]
    d = _descent_direction_stacked(_pad_lanes(c, npm)[None],
                                   _pad_lanes(hc, npm)[None], pre[None])
    return d[0, :, :npk]


def _rayleigh_ritz(c, d, hc, hd, npm: int):
    """Per-k lowest-nb Ritz vectors of span{c, d}; (c', eps ascending).

    Singleton-batch dispatch of :func:`_rayleigh_ritz_stacked` over
    npacked_max-padded blocks — one per-k eager linalg call, counted by
    ``PERK_LINALG_CALLS``.
    """
    global PERK_LINALG_CALLS
    PERK_LINALG_CALLS += 1
    npk = c.shape[-1]
    cp, eps = _rayleigh_ritz_stacked(
        _pad_lanes(c, npm)[None], _pad_lanes(d, npm)[None],
        _pad_lanes(hc, npm)[None], _pad_lanes(hd, npm)[None])
    return cp[0, :, :npk], eps[0]


# ------------------------------------------------- stacked (batched) engine
def _orthonormalize_stacked(c):
    """Batched QR re-orthonormalization over (nk, nbands, npacked_max).

    Each k's matrix is the per-k one with zero rows appended for the
    padded lanes; Householder QR keeps those rows exactly zero (the
    reflectors never mix them in), so padding survives the batched solve
    untouched and the valid lanes match :func:`orthonormalize` bitwise.
    """
    q, r = jnp.linalg.qr(jnp.swapaxes(c, -1, -2))       # (nk, np, nb)
    ph = jnp.sign(jnp.real(
        jnp.diagonal(r, axis1=-2, axis2=-1)) + 1e-30)   # (nk, nb)
    return jnp.swapaxes(q * ph[:, None, :], -1, -2)


def _descent_direction_stacked(c, hc, pre):
    """Batched preconditioned residuals, orthogonal to the current bands.

    The per-k ``_descent_direction`` as three einsums over the stacked
    axis: Rayleigh quotients, the projected gradient, and the
    projection of span{c} out of the preconditioned block.  ``pre`` is
    the masked table, so padded lanes come out exact zeros.
    """
    lam = jnp.real(jnp.sum(jnp.conj(c) * hc, axis=-1))  # (nk, nb)
    grad = hc - lam[..., None] * c
    d = pre[:, None, :] * grad
    ovl = jnp.einsum("kip,kjp->kij", jnp.conj(c), d)    # ⟨c_i|d_j⟩ per k
    return _orthonormalize_stacked(
        d - jnp.einsum("kij,kip->kjp", ovl, c))


def _rayleigh_ritz_stacked(c, d, hc, hd):
    """Batched lowest-nb Ritz vectors of span{c, d} for every k at once.

    One (nk, 2nb, 2nb) blocked Gram build (padded lanes add exact zeros),
    one nk-batched dense ``eigh``, one batched back-rotation — no per-k
    Python dispatch anywhere.  Returns (c', eps) with eps ascending per k.
    """
    nb = c.shape[1]
    bb = jnp.concatenate([c, d], axis=1)                # (nk, 2nb, np)
    hb = jnp.concatenate([hc, hd], axis=1)
    hmat = jnp.einsum("kip,kjp->kij", jnp.conj(bb), hb)
    hmat = 0.5 * (hmat + jnp.conj(jnp.swapaxes(hmat, -1, -2)))
    eps, vecs = jnp.linalg.eigh(hmat)                   # nk-batched solve
    new = jnp.einsum("kin,kip->knp", vecs[:, :, :nb], bb)
    return _orthonormalize_stacked(new), eps[:, :nb]


def update_bands_stacked(basis, c_pad, v_eff, *, steps: int = 3,
                         tables=None, seg: int = 0):
    """Locally-optimal band update on one segment's padded
    (nk_seg, nbands, pad_width) coefficient stack — every stage batched
    over the segment's k-points.

    The per-k math of :func:`update_bands` with the orchestration layer
    removed: each step is two stacked H sweeps (two distributed
    transforms each, via :func:`apply_hamiltonian_padded`), one batched
    descent-direction build, and one nk-batched blocked Rayleigh-Ritz
    solve — a handful of XLA calls total, none of them per-k.  Padded
    lanes carry exact zeros end to end (zero coefficients, zero table
    entries, zero Gram contributions), so results on valid lanes equal
    the per-k path bitwise on CPU.  Fully traceable — the jitted SCF
    step runs it under ``jax.jit`` with donated buffers.

    Returns (updated stack, eigenvalues (nk, nbands) ascending per k,
    H sweeps executed).
    """
    if tables is None:
        tables = basis.stacked_band_tables(seg)
    kin, pre = tables.kinetic, tables.precond
    c = _replicated(basis, c_pad)
    eps = None
    nsweep = 0
    for _ in range(steps):
        hc = _replicated(basis, apply_hamiltonian_padded(basis, c, v_eff,
                                                         kin, seg=seg))
        nsweep += 1
        d = _replicated(basis, _descent_direction_stacked(c, hc, pre))
        hd = _replicated(basis, apply_hamiltonian_padded(basis, d, v_eff,
                                                         kin, seg=seg))
        nsweep += 1
        c, eps = _rayleigh_ritz_stacked(c, d, hc, hd)
    return c, eps, nsweep


def update_bands_all_k(basis, coeffs, v_eff, *, steps: int = 3,
                       stacked: bool | None = None):
    """All-k locally-optimal band update — stacked engine or pipelined per-k.

    The per-k math is :func:`update_bands` exactly — same preconditioner,
    same Rayleigh-Ritz step, same op order within each k.
    ``stacked=None`` (the default) routes through
    :func:`update_bands_stacked` when ``basis.stacks_k`` — the whole
    update runs on one padded (nk, nbands, npacked_max) stack, two
    distributed transforms per sweep and zero per-k Python linalg — and
    falls back to the pipelined per-k loop (k+1's sphere→cube
    all_to_alls dispatched before k's potential apply, Gram/Rayleigh-Ritz
    per k) otherwise; pass True/False to force a path, e.g. to use the
    pipelined loop as the equivalence oracle.  Because no arithmetic
    crosses k-points, both routes match running ``update_bands`` serially
    per k.

    Returns (new coefficient blocks, eigenvalues list [(nbands,)] per k,
    H sweeps executed — each sweep is one H apply per k-point).
    """
    nk = len(coeffs)
    if stacked is None:
        stacked = bool(getattr(basis, "stacks_k", False))
    tr = get_tracer()
    if stacked:
        cs = [None] * nk
        eps_out = [None] * nk
        nsweep = 0
        with tr.span("band_update", route="stacked", nk=nk, steps=steps,
                     segments=len(basis.segments)):
            for s, seg in enumerate(basis.segments):
                inv, _ = basis.stacked_hamiltonian_plans(s)
                c_pad = inv.stack([coeffs[ik] for ik in seg]).reshape(
                    len(seg), inv.nbands, inv.npacked_max)
                c_pad, eps, nsweep = update_bands_stacked(
                    basis, c_pad, v_eff, steps=steps, seg=s)
                outs = inv.split(c_pad.reshape(len(seg) * inv.nbands,
                                               inv.npacked_max))
                for j, ik in enumerate(seg):
                    cs[ik] = outs[j]
                    eps_out[ik] = eps[j]
        return cs, eps_out, nsweep
    cs = [_replicated(basis, c) for c in coeffs]
    npms = [basis.pad_width(ik) for ik in range(nk)]
    pres = [_padded_precond(basis, ik) for ik in range(nk)]
    eps_out = [None] * nk
    nsweep = 0
    for _ in range(steps):
        hcs = [_replicated(basis, hc)
               for hc in apply_hamiltonian_pipelined(basis, cs, v_eff)]
        nsweep += 1
        ds = [_replicated(basis,
                          _descent_direction(cs[ik], hcs[ik], pres[ik],
                                             npms[ik]))
              for ik in range(nk)]
        hds = [_replicated(basis, hd)
               for hd in apply_hamiltonian_pipelined(basis, ds, v_eff)]
        nsweep += 1
        for ik in range(nk):
            cs[ik], eps_out[ik] = _rayleigh_ritz(cs[ik], ds[ik], hcs[ik],
                                                 hds[ik], npms[ik])
    return cs, eps_out, nsweep
