"""G-space Hartree/Poisson solve on the full-cube plan pair.

    v_H(r) = ifft( 4π/|G|² · fft(ρ) ),   G = (2π/L)·fftfreq indices

The forward/inverse cube transforms are the *distributed* FFTB plans from
``basis.cube_plans()`` — the full-cube traffic that interleaves with the
sphere-batch traffic in the paper's workload.  The G=0 (uniform) component
is dropped, i.e. a neutralizing background charge, as in any periodic
Coulomb solve.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp


def coulomb_kernel(n: int, L: float) -> jnp.ndarray:
    """4π/|G|² on the n³ FFT cube in fft-index order, G=0 entry zeroed."""
    f = np.fft.fftfreq(n, d=1.0 / n)            # integer frequencies
    gx, gy, gz = np.meshgrid(f, f, f, indexing="ij")
    g2 = (gx ** 2 + gy ** 2 + gz ** 2) * (2 * np.pi / L) ** 2
    kern = np.where(g2 > 0.0, 4 * np.pi / np.where(g2 > 0.0, g2, 1.0), 0.0)
    return jnp.asarray(kern.astype(np.float32))


class HartreeSolver:
    """Poisson solve + Hartree energy over a PlaneWaveBasis's cube plans."""

    def __init__(self, basis):
        self.basis = basis
        self.kernel = coulomb_kernel(basis.n, basis.L)

    def __call__(self, rho):
        """ρ(r) → v_H(r), both real (n, n, n) fields.

        One forward full-cube plan, a diagonal multiply in G-space, one
        derived-inverse full-cube plan — two distributed transforms.
        """
        fwd, inv = self.basis.cube_plans()
        rho_g = fwd(rho.astype(jnp.complex64))
        return jnp.real(inv(rho_g * self.kernel))

    def energy(self, rho, vh) -> float:
        """E_H = ½ ∫ ρ v_H  (discretized with ΔV)."""
        return float(jnp.sum(rho * vh) * 0.5 * self.basis.dv)
