"""Mixing-driven SCF loop over the plane-wave basis.

Each outer iteration: build v_eff = v_ext + v_H[ρ] + v_xc[ρ], update all
bands at every k-point (batched H applies through cached plans), rebuild
the density from the new orbitals, evaluate the total energy

    E = Σ_k w_k Σ_b f ⟨c|T|c⟩ + ∫ρ v_ext + E_H[ρ] + E_xc[ρ]

and mix ρ_in/ρ_out — plain linear mixing for the warm-up iterations, then
Anderson/Pulay acceleration on the stored residual history.  Convergence is
declared when |ΔE| stays below ``e_tol`` (and the density residual below
``r_tol``) after the warm-up.

The orchestration is eager Python by default: every transform goes
through a plan fetched from the process-global ``PlanCache`` (the per-plan
executors are jitted ``shard_map``s), so the cache's hit counter is the
subsystem's plan-reuse ledger and ``SCFResult.transforms`` counts real
batched 3D transforms.

``SCFConfig(jit_step=True)`` (requires the stacked band-update route)
fuses one whole outer iteration — v_eff build, the stacked band update,
density rebuild, total energy, residual, **and the density mixing** —
into a single jit-compiled step with donated density/band/mixer buffers:
after the first trace, an SCF iteration is one XLA dispatch with zero
per-k Python work.  Plans and band tables are fetched from the PlanCache
eagerly at trace time, so cache traffic stays honestly accounted (it is
counted once per trace, not once per iteration — the whole point);
``SCFResult.transforms`` keeps the same analytic per-iteration count as
the eager path.  The mixer runs in f32 inside the step (the eager
AndersonMixer accumulates its DIIS history in f64), so jitted and eager
runs agree to mixing precision, not bitwise; with plain linear mixing
(``mix_history<=1``) the two paths perform identical f32 arithmetic.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ProcGrid, global_plan_cache
from repro.core.policy import ExecPolicy
from repro.obs.trace import get_tracer

from .basis import PlaneWaveBasis
from .density import (density_from_orbitals, density_from_stacked,
                      electron_count)
from .hamiltonian import (orthonormalize, update_bands, update_bands_all_k,
                          update_bands_stacked)
from .hartree import HartreeSolver
from .potentials import gaussian_wells, lda_exchange


# -------------------------------------------------------------------- mixing
class LinearMixer:
    """ρ ← ρ_in + α (ρ_out − ρ_in)."""

    def __init__(self, alpha: float = 0.5):
        self.alpha = float(alpha)

    def mix(self, rho_in, rho_out):
        return rho_in + self.alpha * (rho_out - rho_in)


class AndersonMixer:
    """Anderson/Pulay (DIIS) density mixing on the residual history.

    Minimizes |Σ_i β_i r_i|² over Σ β_i = 1 (r_i = ρ_out,i − ρ_in,i), then
    takes ρ ← Σ β_i (ρ_in,i + α r_i).  Falls back to linear mixing for the
    first ``warmup`` iterations and whenever the DIIS system is singular.
    """

    def __init__(self, alpha: float = 0.5, history: int = 4,
                 warmup: int = 2):
        self.alpha = float(alpha)
        self.history = int(history)
        self.warmup = int(warmup)
        self._rho_in: list[np.ndarray] = []
        self._res: list[np.ndarray] = []
        self._seen = 0

    def mix(self, rho_in, rho_out):
        rin = np.asarray(rho_in, np.float64).ravel()
        res = np.asarray(rho_out, np.float64).ravel() - rin
        self._rho_in.append(rin)
        self._res.append(res)
        if len(self._res) > self.history:
            self._rho_in.pop(0)
            self._res.pop(0)
        self._seen += 1
        m = len(self._res)
        if self._seen <= self.warmup or m < 2:
            mixed = rin + self.alpha * res
        else:
            r = np.stack(self._res)                       # (m, N)
            a = np.empty((m + 1, m + 1))
            a[:m, :m] = r @ r.T
            a[m, :m] = a[:m, m] = 1.0
            a[m, m] = 0.0
            rhs = np.zeros(m + 1)
            rhs[m] = 1.0
            try:
                beta = np.linalg.solve(a, rhs)[:m]
            except np.linalg.LinAlgError:
                beta = None
            if beta is None or not np.all(np.isfinite(beta)):
                mixed = rin + self.alpha * res
            else:
                mixed = beta @ (np.stack(self._rho_in)
                                + self.alpha * r)
        return jnp.asarray(mixed.astype(np.float32).reshape(rho_in.shape))


# ------------------------------------------------------------- jitted mixing
def jit_mixer_init(nvol: int, history: int):
    """Mixer state for the fused (jit-compiled) SCF step.

    Linear mixing (``history <= 1``) needs only the iteration counter;
    Anderson/Pulay keeps fixed-size ρ_in/residual history buffers (rows
    ordered oldest→newest, zero-filled until ``seen`` fills them) so the
    state is a fixed-shape pytree the step can donate and return.
    """
    state = {"seen": jnp.zeros((), jnp.int32)}
    if history > 1:
        state["rho_in"] = jnp.zeros((history, nvol), jnp.float32)
        state["res"] = jnp.zeros((history, nvol), jnp.float32)
    return state


def jit_mix(state, rho_in, rho_out, *, alpha: float, warmup: int):
    """One mixing step inside the fused sweep; returns (state', ρ_mixed).

    The traceable twin of ``AndersonMixer.mix``/``LinearMixer.mix``: the
    same bordered DIIS system with rows that are not yet (or no longer)
    in the history pinned to identity rows, the same linear-mixing
    fallback for the warm-up iterations and whenever the solve goes
    non-finite.  Runs in f32 (the eager mixer accumulates in f64), and
    with ``history <= 1`` it is exactly the eager linear mixer's f32
    arithmetic.
    """
    rin = rho_in.reshape(-1)
    res = rho_out.reshape(-1) - rin
    seen = state["seen"] + 1
    linear = rin + jnp.float32(alpha) * res
    if "rho_in" not in state:                     # plain linear mixing
        return {"seen": seen}, linear.reshape(rho_in.shape)
    h = state["rho_in"].shape[0]
    rho_hist = jnp.concatenate([state["rho_in"][1:], rin[None]], axis=0)
    res_hist = jnp.concatenate([state["res"][1:], res[None]], axis=0)
    m = jnp.minimum(seen, h)
    valid = jnp.arange(h) >= h - m                # newest rows are valid
    r = res_hist * valid[:, None].astype(res_hist.dtype)
    a = r @ r.T
    vf = valid.astype(a.dtype)
    a = a * (vf[:, None] * vf[None, :])           # invalid rows/cols → 0
    a = a + jnp.diag(1.0 - vf)                    # … pinned to identity
    top = jnp.concatenate([a, vf[:, None]], axis=1)
    bot = jnp.concatenate([vf, jnp.zeros((1,), a.dtype)])[None, :]
    rhs = jnp.zeros((h + 1,), a.dtype).at[h].set(1.0)
    beta = jnp.linalg.solve(jnp.concatenate([top, bot], axis=0), rhs)[:h]
    beta = beta * vf
    mixed = beta @ (rho_hist + jnp.float32(alpha) * res_hist)
    use_linear = ((seen <= warmup) | (m < 2)
                  | ~jnp.all(jnp.isfinite(beta)))
    out = jnp.where(use_linear, linear, mixed)
    state = {"seen": seen, "rho_in": rho_hist, "res": res_hist}
    return state, out.reshape(rho_in.shape)


# -------------------------------------------------------------------- config
@dataclasses.dataclass
class SCFConfig:
    n: int = 16                       # FFT cube width
    diameter: int | None = None       # sphere diameter (default n // 2)
    nbands: int = 4
    nocc: int | None = None           # occupied bands (default: all)
    kpts: tuple = ((0.0, 0.0, 0.0),)  # reduced coords, units 2π/L
    weights: tuple | None = None
    L: float | None = None            # cell side (default n, spacing 1)
    depth: float = 4.0                # Gaussian-well depth
    xc: bool = True                   # include the LDA exchange term
    max_iter: int = 50
    e_tol: float = 1e-5               # |ΔE| convergence threshold
    r_tol: float = 1e-4               # density-residual threshold (per elec)
    inner_steps: int = 4              # band-update steps per k per outer it
    mix_alpha: float = 0.7
    mix_history: int = 5
    mix_warmup: int = 2               # linear iterations before Anderson
    seed: int = 0
    pipeline: bool = True             # double-buffer the per-k transforms
    stack_k: bool | None = None       # ragged-stack the H apply across k
                                      # (None: auto via basis.stacks_k;
                                      # True requires pipeline=True)
    jit_step: bool = False            # fuse mixing + band update + density
                                      # into one jitted step with donated
                                      # buffers (requires the stacked
                                      # band-update route)
    batch_axes: tuple | None = None   # grid axes carrying the band batch
    fft_axes: tuple | None = None     # grid axes carrying the transforms
    segment_padding: float | None = None
                                      # per-segment padding budget for the
                                      # ragged k-stacking (None: one global
                                      # npacked_max segment, the pre-
                                      # segmentation behaviour)
    policy: ExecPolicy | None = None
    backend: str | None = None        # line-DFT backend preference; None
                                      # resolves explicit > policy.backend
                                      # > "matmul" (see PlaneWaveBasis)


@dataclasses.dataclass
class SCFResult:
    converged: bool
    iterations: int
    energy: float
    energies: list[float]             # total energy per outer iteration
    residuals: list[float]            # |ρ_out − ρ_in| per electron
    eigenvalues: np.ndarray           # (nk, nbands), ascending per k
    rho: jnp.ndarray
    transforms: int                   # per-band 3D transforms executed
                                      # (plan calls batch nbands of them)
    seconds: float
    cache_stats: dict                 # global PlanCache counters (delta)
    grid_shape: tuple = ()            # processing-grid shape the run used
    stacked: bool = False             # H sweeps rode the k-stacked batch
    padding_fraction: float = 0.0     # padded lanes / total stacked lanes
    band_update: str = "per-k"        # band-update route: "stacked" (the
                                      # batched engine) or "per-k"
    backend: str = "matmul"           # resolved line-DFT backend the basis
                                      # ran (what plans were built with —
                                      # bench records persist this so a
                                      # silent downgrade is visible)
    segments: int = 1                 # ragged-stacking segment count
    segment_padding_fractions: tuple = ()
                                      # realized per-segment padding, each
                                      # ≤ the configured segment_padding
    jitted: bool = False              # iterations ran as the fused jit step
    #: per-iteration telemetry: one dict per outer iteration with
    #: {iteration, energy, residual, seconds, transforms} — the record
    #: the observability layer attaches so a slow run can be broken
    #: down without re-running under a profiler
    iteration_records: list = dataclasses.field(default_factory=list)

    @property
    def transforms_per_s(self) -> float:
        return self.transforms / max(self.seconds, 1e-9)

    @property
    def seconds_per_iteration(self) -> float:
        """Mean wall time of one outer SCF iteration."""
        return self.seconds / max(self.iterations, 1)


# -------------------------------------------------------------------- energy
def total_energy(basis, coeffs, rho, v_ext, hartree: HartreeSolver, occ,
                 *, xc: bool = True) -> tuple[float, dict]:
    """E[{ψ}, ρ] and its components; ρ should be the orbitals' density."""
    occ = np.asarray(occ, np.float64)
    e_kin = 0.0
    for ik, c in enumerate(coeffs):
        kin = basis.kinetic(ik)
        per_band = jnp.sum(kin[None, :] * jnp.abs(c) ** 2, axis=1)
        e_kin += float(basis.weights[ik]
                       * (occ[ik] @ np.asarray(per_band, np.float64)))
    dv = basis.dv
    e_ext = float(jnp.sum(rho * v_ext) * dv)
    vh = hartree(rho)
    e_h = hartree.energy(rho, vh)
    if xc:
        e_x, _ = lda_exchange(rho)
        e_xc = float(jnp.sum(e_x) * dv)
    else:
        e_xc = 0.0
    total = e_kin + e_ext + e_h + e_xc
    return total, {"kinetic": e_kin, "external": e_ext, "hartree": e_h,
                   "xc": e_xc, "total": total}


def total_energy_stacked(basis, c_pad, rho, v_ext, hartree: HartreeSolver,
                         occ, *, xc: bool = True, tables=None):
    """Traceable E[{ψ}, ρ] on the padded per-segment coefficient stacks.

    ``c_pad`` is either one (nk_seg, nbands, pad_width) stack (the
    single-segment case) or a tuple/list of them, one per basis segment
    in segment order.  The kinetic term is one masked einsum per segment
    against the dense padded kinetic table (padded lanes contribute
    exact zeros), everything else is cube arithmetic — no per-k Python,
    no host transfers, so the fused jit step can inline it.  Accumulates
    in f32 where the eager :func:`total_energy` reduces per-band terms
    in host f64; the two agree to f32 reduction precision (~1e-6 on the
    demo problems).
    """
    if not isinstance(c_pad, (tuple, list)):
        c_pad = (c_pad,)
    if tables is None:
        # eager callers only — the jitted step always passes tables,
        # fetched at trace time, so this branch never runs under tracing
        tables = [basis.stacked_band_tables(s)  # noqa: FFTB202
                  for s in range(len(c_pad))]
    elif not isinstance(tables, (tuple, list)):
        tables = (tables,)
    occ64 = np.asarray(occ, np.float64)  # noqa: FFTB201 — host array
    e_kin = jnp.float32(0.0)
    for s, (cs, tab) in enumerate(zip(c_pad, tables)):
        idx = list(basis.segments[s])
        w = jnp.asarray((basis.weights[idx, None] * occ64[idx]
                         ).astype(np.float32))              # (nk_seg, nb)
        per_band = jnp.sum(tab.kinetic[:, None, :] * jnp.abs(cs) ** 2,
                           axis=-1)
        e_kin = e_kin + jnp.sum(w * per_band)
    dv = jnp.float32(basis.dv)
    e_ext = jnp.sum(rho * v_ext) * dv
    vh = hartree(rho)
    e_h = jnp.sum(rho * vh) * (0.5 * dv)
    e_xc = jnp.sum(lda_exchange(rho)[0]) * dv if xc else 0.0
    return e_kin + e_ext + e_h + e_xc


# -------------------------------------------------------------------- driver
def _jit_scf_loop(cfg: SCFConfig, basis, v_ext, hartree, occ,
                  nelec: float, coeffs, callback):
    """The fused SCF loop: one jit-compiled step per outer iteration.

    Everything the eager loop does per iteration — v_eff build, the
    stacked band update, density rebuild, total energy, residual, density
    mixing — is traced into a single XLA computation with the density,
    band-coefficient and mixer buffers donated, so iterations after the
    first dispatch no per-k Python work at all.  Plans and band tables
    come from the process-global PlanCache *eagerly at trace time* (the
    fetches below and inside the first ``step`` call), which keeps cache
    traffic honestly accounted: one fetch per traced transform, zero per
    steady-state iteration.

    Returns (energies, residuals, eigs, ρ_out, transforms, converged,
    seconds) with the same accounting semantics as the eager loop.
    """
    segs = basis.segments
    invs = [basis.stacked_hamiltonian_plans(s)[0] for s in range(len(segs))]
    tables = [basis.stacked_band_tables(s) for s in range(len(segs))]
    c_segs = tuple(
        invs[s].stack([coeffs[ik] for ik in seg]).reshape(
            len(seg), basis.nbands, invs[s].npacked_max)
        for s, seg in enumerate(segs))
    rho = sum(density_from_stacked(basis, c_segs[s], occ, seg=s)
              for s in range(len(segs)))
    mix_state = jit_mixer_init(basis.n ** 3, cfg.mix_history)
    inelec = 1.0 / max(nelec, 1e-9)

    def step(rho, c_segs, mix_state):
        vh = hartree(rho)
        v_eff = v_ext + vh
        if cfg.xc:
            v_eff = v_eff + lda_exchange(rho)[1]
        c_new = []
        eps_segs = []
        for s in range(len(segs)):
            c_s, eps_s, _ = update_bands_stacked(
                basis, c_segs[s], v_eff, steps=cfg.inner_steps,
                tables=tables[s], seg=s)
            c_new.append(c_s)
            eps_segs.append(eps_s)
        c_new = tuple(c_new)
        rho_out = sum(density_from_stacked(basis, c_new[s], occ, seg=s)
                      for s in range(len(segs)))
        energy = total_energy_stacked(basis, c_new, rho_out, v_ext,
                                      hartree, occ, xc=cfg.xc,
                                      tables=tables)
        resid = (jnp.linalg.norm(rho_out - rho)
                 * jnp.float32(basis.dv ** 0.5 * inelec))
        mix_state, rho_next = jit_mix(mix_state, rho, rho_out,
                                      alpha=cfg.mix_alpha,
                                      warmup=cfg.mix_warmup)
        return (rho_next, c_new, mix_state, rho_out,
                tuple(eps_segs), energy, resid)

    step = jax.jit(step, donate_argnums=(0, 1, 2))

    energies: list[float] = []
    residuals: list[float] = []
    records: list[dict] = []
    eigs = np.zeros((basis.nk, basis.nbands))
    transforms = 0
    converged = False
    rho_out = rho
    # per-iteration analytic transform count, matching the eager loop:
    # Hartree pair + band-update sweeps + density + the energy's Hartree
    per_iter = (2 + 2 * cfg.inner_steps * basis.nk * 2 * basis.nbands
                + basis.nk * basis.nbands + 2)
    tr = get_tracer()
    t0 = time.perf_counter()
    for it in range(cfg.max_iter):
        it_t0 = time.perf_counter()
        with tr.span("scf_iteration", iteration=it, route="jit"):
            rho, c_segs, mix_state, rho_out, eps_segs, energy, resid = \
                step(rho, c_segs, mix_state)
            # the float() conversions sync on the step's outputs, so
            # the span and the per-iteration seconds cover real work
            energy = float(energy)
            resid = float(resid)
        transforms += per_iter
        energies.append(energy)
        residuals.append(resid)
        records.append({"iteration": it, "energy": energy,
                        "residual": resid,
                        "seconds": time.perf_counter() - it_t0,
                        "transforms": per_iter})
        for s, seg in enumerate(segs):
            eigs[list(seg)] = np.asarray(eps_segs[s])
        if callback is not None:
            callback(it, energy, resid)
        if (it > cfg.mix_warmup
                and abs(energies[-1] - energies[-2]) < cfg.e_tol
                and resid < cfg.r_tol):
            converged = True
            break
    # drain the donated buffers before stopping the clock: the scalar
    # syncs above cover the energy/residual path but not necessarily the
    # mixed density still in flight
    jax.block_until_ready((rho, rho_out))
    seconds = time.perf_counter() - t0
    return energies, residuals, records, eigs, rho_out, transforms, \
        converged, seconds


def _init_coefficients(basis, seed: int):
    rng = np.random.default_rng(seed)
    coeffs = []
    for ik in range(basis.nk):
        npk = basis.npacked(ik)
        c = (rng.standard_normal((basis.nbands, npk))
             + 1j * rng.standard_normal((basis.nbands, npk))
             ).astype(np.complex64)
        coeffs.append(orthonormalize(jnp.asarray(c)))
    return coeffs


def run_scf(cfg: SCFConfig, *, grid: ProcGrid | None = None,
            v_ext=None, callback=None) -> SCFResult:
    """Run the SCF loop; see module docstring for the iteration structure.

    ``callback(it, energy, residual)`` is invoked after every outer
    iteration (the example CLI uses it for progress lines).
    """
    basis = PlaneWaveBasis(
        cfg.n, diameter=cfg.diameter, kpts=cfg.kpts, weights=cfg.weights,
        nbands=cfg.nbands, L=cfg.L, grid=grid,
        batch_axes=cfg.batch_axes, fft_axes=cfg.fft_axes,
        segment_padding=cfg.segment_padding,
        policy=cfg.policy, backend=cfg.backend)
    cache0 = dict(global_plan_cache().stats)
    if v_ext is None:
        v_ext = jnp.asarray(gaussian_wells(cfg.n, depth=cfg.depth))
    hartree = HartreeSolver(basis)

    if cfg.inner_steps < 1:
        raise ValueError(f"inner_steps must be >= 1, got {cfg.inner_steps}")
    nocc = cfg.nbands if cfg.nocc is None else int(cfg.nocc)
    if not 0 < nocc <= cfg.nbands:
        raise ValueError(f"nocc {nocc} not in (0, nbands={cfg.nbands}]")
    occ = np.zeros((basis.nk, basis.nbands))
    occ[:, :nocc] = 1.0
    nelec = float(basis.weights.sum() * nocc)

    # route the H sweeps through the ragged k-stacked batch when the grid
    # supports it (or the caller forces it); pipelined per-k is the fallback
    stack_k = basis.stacks_k if cfg.stack_k is None else bool(cfg.stack_k)
    if cfg.stack_k and not cfg.pipeline:
        # stacking IS an all-k sweep — the serial per-k branch cannot
        # honor it, and silently dropping a forced route would lie
        raise ValueError("stack_k=True requires pipeline=True (the "
                         "stacked route sweeps all k-points per step; "
                         "pipeline=False runs the serial per-k loop)")
    stacked = bool(stack_k and cfg.pipeline)
    if cfg.jit_step and not stacked:
        # the fused step is built on the padded stacked engine — running
        # it per-k would re-introduce the dispatch overhead it removes
        raise ValueError("jit_step=True requires the stacked band-update "
                         "route (stack_k=True, or a grid satisfying "
                         "basis.stacks_k with stack_k left on auto)")

    coeffs = _init_coefficients(basis, cfg.seed)

    if cfg.jit_step:
        (energies, residuals, iteration_records, eigs, rho, transforms,
         converged, seconds) = _jit_scf_loop(cfg, basis, v_ext, hartree,
                                             occ, nelec, coeffs, callback)
    else:
        rho = density_from_orbitals(basis, coeffs, occ)
        mixer = AndersonMixer(cfg.mix_alpha, cfg.mix_history,
                              cfg.mix_warmup) \
            if cfg.mix_history > 1 else LinearMixer(cfg.mix_alpha)

        energies = []
        residuals = []
        iteration_records = []
        eigs = np.zeros((basis.nk, basis.nbands))
        # counter and timer both cover the SCF loop only: the warm-up
        # density build above (plan construction + first traces) is
        # excluded from both
        transforms = 0
        converged = False
        tr = get_tracer()
        t0 = time.perf_counter()

        for it in range(cfg.max_iter):
            it_t0 = time.perf_counter()
            it_transforms0 = transforms
            with tr.span("scf_iteration", iteration=it,
                         route="stacked" if stacked else "per-k"):
                vh = hartree(rho)
                transforms += 2                    # cube fwd + derived inv
                v_eff = v_ext + vh
                if cfg.xc:
                    _, v_x = lda_exchange(rho)
                    v_eff = v_eff + v_x
                if cfg.pipeline:
                    # all-k loop: the batched stacked engine (one ragged
                    # nk·nbands stack, einsum Gram/Rayleigh-Ritz) when
                    # the basis stacks k-points, pipelined per-k dispatch
                    # otherwise — per-k math identical to the serial
                    # branch
                    coeffs, eps_list, nsweep = update_bands_all_k(
                        basis, coeffs, v_eff, steps=cfg.inner_steps,
                        stacked=stack_k)
                    for ik in range(basis.nk):
                        eigs[ik] = np.asarray(eps_list[ik])
                    transforms += nsweep * basis.nk * 2 * basis.nbands
                else:
                    for ik in range(basis.nk):
                        coeffs[ik], eps, napply = update_bands(
                            basis, ik, coeffs[ik], v_eff,
                            steps=cfg.inner_steps)
                        eigs[ik] = np.asarray(eps)
                        transforms += napply * 2 * basis.nbands
                rho_out = density_from_orbitals(basis, coeffs, occ)
                transforms += basis.nk * basis.nbands
                energy, _ = total_energy(basis, coeffs, rho_out, v_ext,
                                         hartree, occ, xc=cfg.xc)
                transforms += 2                    # energy's Hartree solve
                # float() syncs on rho_out, closing the span honestly
                resid = float(jnp.linalg.norm(rho_out - rho)
                              * basis.dv ** 0.5) / max(nelec, 1e-9)
            energies.append(energy)
            residuals.append(resid)
            iteration_records.append({
                "iteration": it, "energy": energy, "residual": resid,
                "seconds": time.perf_counter() - it_t0,
                "transforms": transforms - it_transforms0})
            if callback is not None:
                callback(it, energy, resid)
            if (it > cfg.mix_warmup
                    and abs(energies[-1] - energies[-2]) < cfg.e_tol
                    and resid < cfg.r_tol):
                converged = True
                break
            rho = mixer.mix(rho, rho_out)

        jax.block_until_ready(rho)   # drain the last mix before the clock
        seconds = time.perf_counter() - t0
        # return the density the orbitals actually produced (not the mixed
        # guess) — coeffs are unchanged since the loop's last rho_out
        rho = rho_out if energies \
            else density_from_orbitals(basis, coeffs, occ)

    cache1 = global_plan_cache().stats
    delta = {k: cache1[k] - cache0.get(k, 0)
             for k in ("hits", "misses", "evictions")}
    delta["size"] = cache1["size"]
    assert abs(electron_count(basis, rho) - nelec) < 1e-3 * max(nelec, 1.0)
    padding = basis.padding_fraction if stacked else 0.0
    return SCFResult(
        converged=converged, iterations=len(energies),
        energy=energies[-1] if energies else float("nan"),
        energies=energies, residuals=residuals, eigenvalues=eigs, rho=rho,
        transforms=transforms, seconds=seconds, cache_stats=delta,
        grid_shape=tuple(basis.grid.shape), stacked=stacked,
        padding_fraction=padding,
        band_update="stacked" if stacked else "per-k",
        backend=basis.backend,
        jitted=bool(cfg.jit_step),
        segments=basis.nsegments,
        segment_padding_fractions=basis.segment_padding_fractions,
        iteration_records=iteration_records)
