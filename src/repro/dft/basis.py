"""Per-k-point plane-wave bases — a batch of *different* spheres.

Every k-point carries its own cut-off sphere: the Bloch factor e^{ik·r}
shifts the kinetic-energy paraboloid, so the set of plane waves with
½|G+k|² ≤ E_cut is a sphere whose *center* moves with k (paper §2.2 — "one
sphere per k-point, bands batched within each").  All spheres share one
d³ bounding box and one n³ FFT cube, so every k-point's transform has the
same data layout but a *different* static pack/unpack table — exactly the
multi-plan traffic the process-global ``PlanCache`` exists for: distinct
spheres build distinct plans, repeated spheres (and every later SCF
iteration) hit the cache.

Units: cubic cell of side ``L`` (default: ``n`` grid spacings of 1), so a
reciprocal-lattice step is 2π/L.  k-points are given in reduced coordinates
(units of 2π/L).  The sphere is centered at c_k = c0 + k, and the kinetic
energy of packed coefficient at cube index ``idx`` is
½(2π/L)²|idx − c_k|² — the cut-off rule and the kinetic ladder agree by
construction.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import Domain, ProcGrid, SphereDomain, fftb
from repro.core.policy import ExecPolicy

#: sphere bounding-cube (bands, x, y, z) → real-space cube, x/Z sharded
PW_SPEC = "b x{0} y z -> b X Y Z{0}"
#: full density/potential cube, real space (z-sharded) → G space (Z-sharded)
CUBE_SPEC = "x y z{0} -> X Y Z{0}"


class PlaneWaveBasis:
    """Shared FFT cube + per-k-point spheres, plans served from the cache.

    Plans are *not* stored on the instance: ``plans_for_k``/``cube_plans``
    go through ``fftb.plan_for`` (the process-global ``PlanCache``) on every
    call, so plan reuse across SCF iterations — and across bases that happen
    to request the same sphere — is the cache's hit counter, not a private
    dict.  Derived mirrors are memoized on the plan itself (``inverse()``),
    so a pair costs one schedule search process-wide.
    """

    def __init__(self, n: int, *, diameter: int | None = None,
                 kpts=((0.0, 0.0, 0.0),), weights=None, nbands: int = 4,
                 L: float | None = None, grid: ProcGrid | None = None,
                 policy: ExecPolicy | None = None, backend: str = "matmul"):
        self.n = int(n)
        self.d = int(diameter) if diameter is not None else self.n // 2
        if not 0 < self.d <= self.n:
            raise ValueError(f"sphere diameter {self.d} not in (0, {n}]")
        self.L = float(L) if L is not None else float(n)
        self.grid = grid if grid is not None else \
            ProcGrid.create([jax.device_count()])
        self.nbands = int(nbands)
        self.policy = policy
        self.backend = backend

        self.kpts = np.atleast_2d(np.asarray(kpts, np.float64))
        if self.kpts.shape[1] != 3:
            raise ValueError(f"kpts must be (nk, 3), got {self.kpts.shape}")
        nk = self.kpts.shape[0]
        if weights is None:
            self.weights = np.full(nk, 1.0 / nk)
        else:
            self.weights = np.asarray(weights, np.float64)
            if self.weights.shape != (nk,):
                raise ValueError("one weight per k-point")
            self.weights = self.weights / self.weights.sum()

        c0 = (self.d - 1) / 2.0
        self.spheres = [
            SphereDomain(radius=self.d / 2.0,
                         center=tuple(c0 + k for k in kp),
                         lower=(0, 0, 0),
                         upper=(self.d - 1,) * 3)
            for kp in self.kpts
        ]
        self.bdom = Domain((0,), (self.nbands - 1,))
        self.cube = Domain((0, 0, 0), (self.n - 1,) * 3)
        self._kin = [None] * nk
        self._gvec = [None] * nk

    # ----------------------------------------------------------------- size
    @property
    def nk(self) -> int:
        return self.kpts.shape[0]

    @property
    def cell_volume(self) -> float:
        return self.L ** 3

    @property
    def dv(self) -> float:
        """Real-space integration element ΔV = Ω / n³."""
        return (self.L / self.n) ** 3

    def npacked(self, ik: int) -> int:
        return self.spheres[ik].npacked

    # ------------------------------------------------------- G bookkeeping
    def gvectors(self, ik: int) -> np.ndarray:
        """(npacked, 3) G+k offsets from the sphere center, in units 2π/L.

        CSR (pack) order — aligned with the packed coefficient vector."""
        if self._gvec[ik] is None:
            sph = self.spheres[ik]
            ex, ey, ez = sph.extents
            flat = sph.pack_indices()
            idx = np.stack([flat // (ey * ez), (flat // ez) % ey,
                            flat % ez], axis=1).astype(np.float64)
            self._gvec[ik] = idx - np.asarray(sph.center)
        return self._gvec[ik]

    def kinetic(self, ik: int):
        """½|G+k|² diagonal over packed coefficients (f32, on device)."""
        if self._kin[ik] is None:
            g = self.gvectors(ik)
            g2 = (g ** 2).sum(1) * (2 * np.pi / self.L) ** 2
            self._kin[ik] = jnp.asarray(0.5 * g2.astype(np.float32))
        return self._kin[ik]

    # ----------------------------------------------------------------- plans
    def plans_for_k(self, ik: int):
        """(inverse, forward) sphere↔cube pair for k-point ``ik``.

        Served from the process-global PlanCache — the first request per
        distinct sphere builds (one schedule search), every later request
        (same k re-visited, next SCF iteration, a symmetry-equivalent
        k-point) is a cache hit.
        """
        inv = fftb.plan_for(
            PW_SPEC, domains=(self.bdom, self.spheres[ik]), grid=self.grid,
            sizes=(self.n,) * 3, inverse=True, backend=self.backend,
            policy=self.policy)
        return inv, inv.inverse()       # mirror is memoized on the plan

    def cube_plans(self):
        """(forward, inverse) full-cube pair for density/potential fields."""
        fwd = fftb.plan_for(
            CUBE_SPEC, domains=self.cube, grid=self.grid,
            backend=self.backend, policy=self.policy)
        return fwd, fwd.inverse()       # mirror is memoized on the plan

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"PlaneWaveBasis(n={self.n}, d={self.d}, nk={self.nk}, "
                f"nbands={self.nbands}, grid={self.grid})")
