"""Per-k-point plane-wave bases — a batch of *different* spheres.

Every k-point carries its own cut-off sphere: the Bloch factor e^{ik·r}
shifts the kinetic-energy paraboloid, so the set of plane waves with
½|G+k|² ≤ E_cut is a sphere whose *center* moves with k (paper §2.2 — "one
sphere per k-point, bands batched within each").  All spheres share one
d³ bounding box and one n³ FFT cube, so every k-point's transform has the
same data layout but a *different* static pack/unpack table — exactly the
multi-plan traffic the process-global ``PlanCache`` exists for: distinct
spheres build distinct plans, repeated spheres (and every later SCF
iteration) hit the cache.

Processing grids (paper §3.3): the basis runs on 1D fft-only grids *or* 2D
(batch × fft) grids.  On a 2D grid the band batch is sharded over the batch
axes and only the fft axes carry the transforms' all_to_alls — the paper's
headline configuration, which keeps scaling after the fft axes saturate the
sphere diameter.  When ``nk`` divides the batch-axis size, the density
build additionally stacks k-points into the batch dimension (one transform
of batch nk·nbands), so k-points are genuinely distributed too.

Units: cubic cell of side ``L`` (default: ``n`` grid spacings of 1), so a
reciprocal-lattice step is 2π/L.  k-points are given in reduced coordinates
(units of 2π/L).  The sphere is centered at c_k = c0 + k, and the kinetic
energy of packed coefficient at cube index ``idx`` is
½(2π/L)²|idx − c_k|² — the cut-off rule and the kinetic ladder agree by
construction.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (Domain, ProcGrid, cube_spec, fftb,
                        global_plan_cache, kpoint_sphere,
                        make_stacked_planewave_pair, padded_kinetic_table,
                        planewave_spec, segment_padding_fraction,
                        segment_spheres, sphere_gvectors, sphere_kinetic_row)
from repro.check.diagnostics import raise_if_errors
from repro.check.preflight import preflight_basis
from repro.core.cache import domains_key, grid_key
from repro.core.policy import ExecPolicy

#: sphere bounding-cube (bands, x, y, z) → real-space cube, x/Z sharded
#: (the 1D fft-only layout; 2D grids derive their spec via planewave_spec)
PW_SPEC = planewave_spec()
#: full density/potential cube, real space (z-sharded) → G space (Z-sharded)
CUBE_SPEC = cube_spec()


@dataclasses.dataclass(frozen=True)
class StackedBandTables:
    """Dense per-k tables for the batched band-update engine.

    All three are ``(nk, npacked_max)`` float32 arrays, pinned replicated
    on the basis's mesh, with **exact zeros** on padded lanes — so they
    can ride batched einsums over the full ``(nk, nbands, npacked_max)``
    coefficient stack, and padded lanes contribute exact zeros to every
    Gram matrix, energy and preconditioned residual without any runtime
    masking:

      * ``kinetic``  — ½|G+k|² diagonal (bitwise-equal to the per-k
        :meth:`PlaneWaveBasis.kinetic` ladders on valid lanes),
      * ``mask``     — lane validity as {0.0, 1.0},
      * ``precond``  — the masked Teter-style damping mask/(1 + ½|G+k|²).

    Cached in the process-global ``PlanCache`` next to the stacked plan
    pair (same key ingredients), so every SCF iteration after the first
    is a cache hit; the cache bills the three tables as private bytes.
    """

    kinetic: jnp.ndarray
    mask: jnp.ndarray
    precond: jnp.ndarray

    # ------------------------------------------- PlanCache accounting
    def private_bytes(self) -> int:
        return sum(int(a.nbytes)
                   for a in (self.kinetic, self.mask, self.precond))

    def shared_table_bytes(self) -> dict:
        return {}

    def estimated_bytes(self) -> int:
        return self.private_bytes()


class PlaneWaveBasis:
    """Shared FFT cube + per-k-point spheres, plans served from the cache.

    Plans are *not* stored on the instance: ``plans_for_k``/``cube_plans``
    go through ``fftb.plan_for`` (the process-global ``PlanCache``) on every
    call, so plan reuse across SCF iterations — and across bases that happen
    to request the same sphere — is the cache's hit counter, not a private
    dict.  Derived mirrors are memoized on the plan itself (``inverse()``),
    so a pair costs one schedule search process-wide.

    ``grid`` may be 1D (fft-only, the former pinned layout) or multi-axis.
    On a multi-axis grid ``batch_axes``/``fft_axes`` split the grid axes
    between the band batch and the transform dims; by default the first
    axis is batch and the rest are fft — a ``(batch, fft)`` mesh, or the
    pencil ``(batch, fft, fft)`` mesh on 3-axis grids (both transform
    dims sharded, every all_to_all over one small axis).

    ``segment_padding`` switches the ragged k-stacking from one global
    ``npacked_max`` pad target to **segmented** stacking: k-points are
    grouped into similar-``npacked`` segments (``core.segment_spheres``)
    whose realized padding fraction never exceeds the budget, and every
    stacked plan/table method takes the segment index.  ``None`` (the
    default) keeps the single full-batch segment — all existing
    single-segment behaviour, cache keys included, is unchanged.
    """

    def __init__(self, n: int, *, diameter: int | None = None,
                 kpts=((0.0, 0.0, 0.0),), weights=None, nbands: int = 4,
                 L: float | None = None, grid: ProcGrid | None = None,
                 batch_axes: tuple[int, ...] | None = None,
                 fft_axes: tuple[int, ...] | None = None,
                 segment_padding: float | None = None,
                 policy: ExecPolicy | None = None,
                 backend: str | None = None):
        self.n = int(n)
        self.d = int(diameter) if diameter is not None else self.n // 2
        self.L = float(L) if L is not None else float(n)
        self.grid = grid if grid is not None else \
            ProcGrid.create([jax.device_count()])
        self.nbands = int(nbands)
        self.policy = policy
        # backend resolution ladder: explicit argument > policy preference
        # > the "matmul" default.  The resolved value is what every plan
        # request below carries — callers read ``basis.backend`` to learn
        # what the run actually asked for (bench records persist it).
        if backend is None:
            backend = policy.backend if policy is not None and \
                policy.backend is not None else "matmul"
        self.backend = backend

        if batch_axes is None:
            # (batch, fft, …) convention: the first axis carries the band
            # batch, every remaining axis transforms — (batch, fft) on 2D
            # grids, the pencil (batch, fft, fft) on 3D; 1D stays fft-only
            batch_axes = () if self.grid.ndim == 1 else (0,)
        self.batch_axes = tuple(batch_axes)
        if fft_axes is None:
            fft_axes = tuple(a for a in range(self.grid.ndim)
                             if a not in self.batch_axes)
        self.fft_axes = tuple(fft_axes)
        # coded preflight diagnostics (FFTB110–117) replace the former
        # ad-hoc ValueErrors; DiagnosticError is a ValueError carrying
        # the same message substrings, so existing handlers keep working
        raise_if_errors(preflight_basis(
            self.n, diameter=self.d, kpts=kpts, nbands=self.nbands,
            grid=self.grid, batch_axes=self.batch_axes,
            fft_axes=self.fft_axes, segment_padding=segment_padding,
            backend=self.backend))
        self.batch_procs = math.prod(
            self.grid.axis_size(a) for a in self.batch_axes)
        self.fft_procs = math.prod(
            self.grid.axis_size(a) for a in self.fft_axes)
        self._pw_spec = planewave_spec(self.batch_axes, self.fft_axes)
        self._cube_spec = cube_spec(self.fft_axes)

        self.kpts = np.atleast_2d(np.asarray(kpts, np.float64))
        nk = self.kpts.shape[0]
        if weights is None:
            self.weights = np.full(nk, 1.0 / nk)
        else:
            self.weights = np.asarray(weights, np.float64)
            if self.weights.shape != (nk,):
                raise ValueError("one weight per k-point")
            self.weights = self.weights / self.weights.sum()

        # one construction rule shared with the transform service: same
        # cutoff ⇒ same bounding box ⇒ batch-compatible pack tables
        self.spheres = [kpoint_sphere(self.d, kp) for kp in self.kpts]
        self.bdom = Domain((0,), (self.nbands - 1,))
        self.cube = Domain((0, 0, 0), (self.n - 1,) * 3)
        self._kin = [None] * nk
        self._gvec = [None] * nk

        # segmented ragged stacking: partition k-points into similar-
        # npacked segments under the padding budget; segment sizes are
        # constrained to divide the batch-axis size so every segment's
        # stacked nk_seg·nbands batch keeps the stacks_k sharding
        # contract.  The default (None) is the single full-batch segment
        # in k order — bitwise and cache-key identical to the
        # pre-segmentation behaviour.
        self.segment_padding = (float(segment_padding)
                                if segment_padding is not None else None)
        if self.segment_padding is None:
            self.segments: tuple[tuple[int, ...], ...] = (tuple(range(nk)),)
        else:
            div = self.batch_procs if self.batch_procs > 1 else None
            self.segments = segment_spheres(
                self.spheres, self.segment_padding, size_divisor=div)
        self._seg_of = [0] * nk
        for s, seg in enumerate(self.segments):
            for i in seg:
                self._seg_of[i] = s

    # ----------------------------------------------------------------- size
    @property
    def nk(self) -> int:
        return self.kpts.shape[0]

    @property
    def cell_volume(self) -> float:
        return self.L ** 3

    @property
    def dv(self) -> float:
        """Real-space integration element ΔV = Ω / n³."""
        return (self.L / self.n) ** 3

    def npacked(self, ik: int) -> int:
        return self.spheres[ik].npacked

    @property
    def npacked_max(self) -> int:
        """max_k npacked(k) — the padded lane count of the stacked batch.

        Both band-update engines run their Gram/Rayleigh-Ritz contractions
        over exactly this many lanes (padded with exact zeros) *within a
        segment* (``pad_width``), so the per-k and stacked paths share
        one rounding behaviour; see ``dft.hamiltonian``.
        """
        return max(s.npacked for s in self.spheres)

    # ------------------------------------------------------------ segments
    @property
    def nsegments(self) -> int:
        return len(self.segments)

    def seg_of(self, ik: int) -> int:
        """Index of the segment k-point ``ik`` stacks into."""
        return self._seg_of[ik]

    def pad_width(self, ik: int) -> int:
        """Padded lane count of k-point ``ik``'s segment.

        Both band-update engines contract their linalg over exactly this
        many lanes for ``ik`` — the per-k oracle pads to it so stacked
        and per-k execute identical GEMM shapes (bitwise agreement).
        With the default single segment this is ``npacked_max``.
        """
        seg = self.segments[self._seg_of[ik]]
        return max(self.spheres[i].npacked for i in seg)

    @property
    def padding_fraction(self) -> float:
        """Padded lanes / total lanes over all segments.

        With one segment this equals the stacked plan pair's
        ``padding_fraction``; segmentation can only lower it.
        """
        used = sum(s.npacked for s in self.spheres)
        lanes = sum(len(seg) * max(self.spheres[i].npacked for i in seg)
                    for seg in self.segments)
        return 1.0 - used / float(lanes)

    @property
    def segment_padding_fractions(self) -> tuple[float, ...]:
        """Realized per-segment padding — each ≤ ``segment_padding``."""
        return tuple(segment_padding_fraction(self.spheres, seg)
                     for seg in self.segments)

    @property
    def stacks_k(self) -> bool:
        """True when k-points stack into the transforms' batch dimension.

        On a (batch × fft) grid, every segment's nk_seg·nbands stacked
        batch must split evenly over the batch axes — segment length
        divides the batch-axis size and nk_seg·nbands is divisible by it
        — so k-points (not just bands) are sharded.  Both the density
        build and the Hamiltonian apply route through the stacked plans
        then — one batched transform per direction per segment instead
        of nk per-k dispatches (the pipelined per-k path remains as the
        fallback and oracle).  With the default single segment this is
        the original ``nk | batch_procs`` condition; segmentation can
        *restore* stacking for k-counts that do not divide the batch
        axis (the segmenter caps segment sizes at divisors of it).
        """
        return (bool(self.batch_axes) and self.nk > 1
                and self.batch_procs > 1
                and all(self.batch_procs % len(seg) == 0
                        and (len(seg) * self.nbands) % self.batch_procs == 0
                        for seg in self.segments))

    # ------------------------------------------------------- G bookkeeping
    def gvectors(self, ik: int) -> np.ndarray:
        """(npacked, 3) G+k offsets from the sphere center, in units 2π/L.

        CSR (pack) order — aligned with the packed coefficient vector.
        Delegates to ``core.planewave.sphere_gvectors``, the same decode
        the padded dense tables use."""
        if self._gvec[ik] is None:
            self._gvec[ik] = sphere_gvectors(self.spheres[ik])
        return self._gvec[ik]

    def kinetic(self, ik: int):
        """½|G+k|² diagonal over packed coefficients (f32, on device).

        The same ``sphere_kinetic_row`` pipeline that fills the padded
        table in :meth:`stacked_band_tables`, so the two agree bitwise
        by construction."""
        if self._kin[ik] is None:
            self._kin[ik] = jnp.asarray(
                sphere_kinetic_row(self.spheres[ik], self.L))
        return self._kin[ik]

    # ----------------------------------------------------------------- plans
    def plans_for_k(self, ik: int):
        """(inverse, forward) sphere↔cube pair for k-point ``ik``.

        Served from the process-global PlanCache — the first request per
        distinct sphere builds (one schedule search), every later request
        (same k re-visited, next SCF iteration, a symmetry-equivalent
        k-point) is a cache hit.  On a 2D grid the band batch rides the
        batch axes, the staged transposes ride the fft axes.
        """
        inv = fftb.plan_for(
            self._pw_spec, domains=(self.bdom, self.spheres[ik]),
            grid=self.grid, sizes=(self.n,) * 3, inverse=True,
            backend=self.backend, policy=self.policy)
        return inv, inv.inverse()       # mirror is memoized on the plan

    def _seg_spheres(self, seg: int):
        """The segment's spheres, in segment (stack) order."""
        return tuple(self.spheres[i] for i in self.segments[seg])

    def stacked_inverse_plan(self, seg: int = 0):
        """One d³→n³ inverse plan batching segment ``seg``'s orbitals.

        The spheres differ only in their pack tables; the staged-padding
        FFT itself sees the shared d³ bounding box, so every k-point's
        cube can ride a single transform whose batch dim is
        nk_seg·nbands — sharding *k-points and bands* over the batch
        axes.  Equal-sized segments resolve to the *same* cache entry
        (the batch domain is the only per-segment key ingredient), so
        segmentation multiplies pack tables, not schedule searches.
        Used by the density build when :attr:`stacks_k` holds.
        """
        nks = len(self.segments[seg])
        bdom = Domain((0,), (nks * self.nbands - 1,))
        bbox = Domain((0, 0, 0), (self.d - 1,) * 3)
        return fftb.plan_for(
            self._pw_spec, domains=(bdom, bbox), grid=self.grid,
            sizes=(self.n,) * 3, inverse=True, backend=self.backend,
            policy=self.policy)

    def stacked_hamiltonian_plans(self, seg: int = 0):
        """(inverse, forward) ragged-batch stacked pair for the H apply.

        One ``StackedPlaneWaveFFT`` pair batching segment ``seg``'s
        nk_seg·nbands orbitals: each k-point's packed coefficients are
        padded to the segment's own lane width (``pad_width``) with the
        per-k validity baked into the pack/unpack index tables, so one
        Hamiltonian sweep is two batched distributed transforms per
        segment regardless of nk and nbands.  Served from the
        process-global PlanCache keyed by the segment's sphere set; the
        inner d³→n³ plan is :meth:`stacked_inverse_plan` — shared
        (object identity and cache accounting alike) with the density
        build and with every equal-sized segment.
        """
        spheres = self._seg_spheres(seg)
        cache = global_plan_cache()
        key = ("stacked-pw", self._pw_spec,
               domains_key(spheres), (len(spheres), self.nbands),
               grid_key(self.grid), (self.n,) * 3, self.backend,
               self.policy)
        inv = cache.get_or_build(
            key, lambda: make_stacked_planewave_pair(
                self.grid, self.n, list(spheres), self.nbands,
                backend=self.backend, batch_axes=self.batch_axes,
                fft_axes=self.fft_axes, policy=self.policy,
                plan=self.stacked_inverse_plan(seg))[0])
        return inv, inv.inverse()   # mirror is memoized on the plan

    def stacked_band_tables(self, seg: int = 0) -> StackedBandTables:
        """Dense kinetic/mask/precond tables for the stacked band update.

        Per segment — ``(nk_seg, pad_width)`` rows in segment order.
        Served from the process-global PlanCache alongside the stacked
        plan pair: the first request per sphere set builds the padded
        tables (host-side numpy + one replicated device_put), every later
        request — the next band sweep, the next SCF iteration — is a
        cache hit.  Values on valid lanes match the per-k ladders bitwise
        (same float64→float32 pipeline for ``kinetic``, the same float32
        ``1/(1 + kin)`` arithmetic for ``precond``), padded lanes are
        exact zeros in all three tables.
        """
        spheres = self._seg_spheres(seg)
        cache = global_plan_cache()
        key = ("stacked-band-tables", domains_key(spheres),
               (len(spheres), self.nbands), grid_key(self.grid), self.L)
        return cache.get_or_build(
            key, lambda: self._build_band_tables(spheres))

    def _build_band_tables(self, spheres) -> StackedBandTables:
        kin_np, valid = padded_kinetic_table(list(spheres), self.L)
        kin = self.grid.replicate(jnp.asarray(kin_np))
        mask = self.grid.replicate(
            jnp.asarray(valid.astype(np.float32)))
        # same f32 ops as the per-k 1/(1 + kinetic(ik)) preconditioner, so
        # valid lanes agree bitwise; mask zeroes the padded lanes exactly
        precond = self.grid.replicate(mask / (1.0 + kin))
        return StackedBandTables(kinetic=kin, mask=mask, precond=precond)

    def cube_plans(self):
        """(forward, inverse) full-cube pair for density/potential fields."""
        fwd = fftb.plan_for(
            self._cube_spec, domains=self.cube, grid=self.grid,
            backend=self.backend, policy=self.policy)
        return fwd, fwd.inverse()       # mirror is memoized on the plan

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"PlaneWaveBasis(n={self.n}, d={self.d}, nk={self.nk}, "
                f"nbands={self.nbands}, grid={self.grid}, "
                f"batch_axes={self.batch_axes}, fft_axes={self.fft_axes}, "
                f"segments={len(self.segments)})")
