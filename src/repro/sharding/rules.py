"""Parallelism rules: DP(pod,data) × TP/EP(model) × FSDP(data).

Param specs are derived from leaf *path names* (the same rule table covers
every family since modules share naming conventions).  The model runs under
`jax.jit` with NamedSharding constraints (GSPMD auto-partitioning tolerates
non-divisible dims — e.g. 8 KV heads on a 16-way model axis, 40 experts on
16 — by padding); the FFTB core keeps explicit shard_map collectives.

Weights: 2-D leaves shard (in_dim → "data" [FSDP], out_dim → "model" [TP])
or the transpose for output projections, vocab over "model"; stacked-layer
leading dims are unsharded.  `pod` is pure DP: params replicated across
pods, gradient all-reduce crosses pods (hierarchical under GSPMD).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf-name → spec for the *trailing* dims (leading stack dims padded None).
# "fsdp" resolves to ("pod","data") on multi-pod meshes (ZeRO spans pods),
# plain "data" otherwise.
_RULES: list[tuple[str, tuple]] = [
    (r"^(embed)$",                       ("model", "fsdp")),
    (r"^(lm_head)$",                     ("fsdp", "model")),
    # column-parallel (input proj): in_dim FSDP, out_dim TP
    (r"^(wq|wk|wv|w_up|w_gate|w_x|w_gate_in|in_proj|w_r|w_i)$",
     ("fsdp", "model")),
    # row-parallel (output proj): in_dim TP, out_dim FSDP
    (r"^(wo|w_down|out_proj|w_out)$",    ("model", "fsdp")),
    (r"^(router)$",                      ("fsdp", None)),
    (r"^(conv_w)$",                      (None, "model")),
]
# MoE expert-stacked tensors (E, D, F)/(E, F, D): experts over "model" (EP)
_MOE_RULES = {
    "w_up": ("model", "fsdp", None),
    "w_gate": ("model", "fsdp", None),
    "w_down": ("model", None, "fsdp"),
}


def _resolve(entry, mesh: Mesh | None):
    if entry != "fsdp":
        return entry
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return "data"


def _axes_size(entry, mesh: Mesh) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def drop_indivisible(spec: P, shape, mesh: Mesh | None) -> P:
    """jit in_shardings reject uneven dims — replicate those instead.

    (with_sharding_constraint tolerates padding; argument shardings don't,
    e.g. granite's vocab 49155 or 8 KV heads on the 16-way model axis.)
    """
    if mesh is None:
        return spec
    ent = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, ent):
        e = e if (e is None or dim % _axes_size(e, mesh) == 0) else None
        if isinstance(e, tuple) and len(e) == 1:
            e = e[0]          # old JAX keeps ("data",) distinct from "data"
        out.append(e)
    return P(*out)


def _leaf_spec(path, leaf, mesh=None) -> P:
    names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    name = names[-1]
    ndim = leaf.ndim
    in_moe = "moe" in names
    base = None
    if in_moe and name in _MOE_RULES:
        base = _MOE_RULES[name]
    else:
        for pat, spec in _RULES:
            if re.match(pat, name):
                base = spec
                break
    if base is None or ndim < len(base):
        return P()                                   # replicate (norms etc.)
    pad = (None,) * (ndim - len(base))
    spec = P(*(pad + tuple(_resolve(e, mesh) for e in base)))
    return drop_indivisible(spec, leaf.shape, mesh)


def param_specs(params, mesh: Mesh | None = None) -> dict:
    """Pytree of PartitionSpec matching ``params`` (shapes or arrays)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, mesh), params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


# ------------------------------------------------------------- activations
def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_axis(mesh: Mesh, batch: int):
    """Shard batch over (pod, data) when divisible, else replicate."""
    axes = _dp_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return axes if (batch % n == 0 and batch >= n) else None


def data_specs(cfg, shape, mesh: Mesh) -> dict:
    """PartitionSpecs for one batch of inputs for (cfg × shape)."""
    b = batch_axis(mesh, shape.batch)
    specs = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.family == "vlm":
        specs["image_embeds"] = P(b, None, None)
    if cfg.family == "encdec":
        specs["frames"] = P(b, None, None)
    return specs


def cache_specs(cfg, batch: int, mesh: Mesh, cache) -> dict:
    """KV/state cache specs: batch over DP axes, heads/features over model.

    KV-head counts often don't divide the model axis (GQA kv=8 on 16) —
    fall back to sharding head_dim, then replicate (drop_indivisible)."""
    b = batch_axis(mesh, batch)

    def spec(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = names[-1] if names else ""
        nd = leaf.ndim
        if name in ("k", "v", "xk", "xv"):      # (L, B, S, Kh, hd)
            s = P(None, b, None, "model", None)
            if leaf.shape[3] % mesh.shape["model"]:
                s = P(None, b, None, None, "model")
        elif name == "ssm":                     # (L, B, H, N, P)
            s = P(None, b, "model", None, None)
        elif name == "conv":                    # (L, B, K-1, C)
            s = P(None, b, None, "model")
        elif name == "h":                       # (L, B, R)
            s = P(None, b, "model")
        else:
            s = P(*((None,) * nd))
        return drop_indivisible(s, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, cache)


def logical_axis_env(mesh: Mesh):
    """Context manager: set mesh for with_sharding_constraint use."""
    return jax.sharding.use_mesh(mesh)
