"""Ambient sharding context: lets model code pin activation shardings
without threading mesh objects through every layer.

GSPMD propagation from param/input shardings alone mis-places the batch
dim around gather/one-hot patterns (observed: the xent chunk replicating
the global batch → 144 GiB temps).  The trainer/dry-run installs the mesh
+ batch axes here; models call `constrain_batch`/`constrain` at the few
load-bearing points (embed output, loss chunks, layer boundaries)."""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None
_BATCH_AXES = None       # tuple[str,...] | None
_SEQ_AXIS = None         # str | None — sequence parallelism (Megatron-SP)


@contextlib.contextmanager
def use(mesh: Mesh, batch_axes, seq_axis=None):
    global _MESH, _BATCH_AXES, _SEQ_AXIS
    old = (_MESH, _BATCH_AXES, _SEQ_AXIS)
    _MESH, _BATCH_AXES, _SEQ_AXIS = mesh, batch_axes, seq_axis
    try:
        yield
    finally:
        _MESH, _BATCH_AXES, _SEQ_AXIS = old


def active() -> bool:
    return _MESH is not None


def constrain(x, *entries):
    """with_sharding_constraint(x, P(*entries)) if a mesh is installed.

    Entries may use the sentinel "batch" → the installed batch axes.
    """
    if _MESH is None:
        return x
    spec = []
    for e in entries:
        if e == "batch":
            spec.append(_BATCH_AXES)
        elif isinstance(e, str) and e not in _MESH.axis_names:
            spec.append(None)
        else:
            spec.append(e)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*spec)))


def axis_size(name: str):
    """Size of a mesh axis, or None when no mesh is installed."""
    if _MESH is None or name not in _MESH.axis_names:
        return None
    return _MESH.shape[name]


def batch_size():
    if _MESH is None or not _BATCH_AXES:
        return None
    n = 1
    for a in _BATCH_AXES:
        n *= _MESH.shape[a]
    return n


def constrain_batch(x):
    """Shard dim 0 over the batch axes, replicate the rest."""
    if _MESH is None:
        return x
    return constrain(x, "batch", *([None] * (x.ndim - 1)))


def constrain_act(x):
    """Layer-boundary activations (B, S, D): batch over DP axes and —
    when sequence parallelism is on — S over the model axis (Megatron-SP:
    the residual stream and the remat stack shrink by the TP degree; GSPMD
    inserts the all-gather/reduce-scatter pairs around attention/MLP)."""
    if _MESH is None:
        return x
    if (_SEQ_AXIS is not None and x.ndim == 3
            and x.shape[1] % _MESH.shape[_SEQ_AXIS] == 0):
        return constrain(x, "batch", _SEQ_AXIS,
                         *([None] * (x.ndim - 2)))
    return constrain_batch(x)
