"""Process-grid selection for the DFT workload: 1D fft, 2D batch×fft, or
3-axis (batch, fft, fft) pencil grids.

The paper's §3.3 argument: once the fft axes saturate what the sphere
diameter can absorb (an all_to_all needs the moved dim divisible by the
axis size, and message sizes shrink linearly with it), the *batch*
dimension — bands, and k-points stacked with them — is the axis that keeps
scaling.  A single fft axis saturates quickly though (``pf ≤
diameter / max_fft_fraction``), which is exactly why pencil-style 2D fft
decompositions are the canonical scale-out shape (P3DFFT, and the flexible
schedules this repo reproduces): splitting the transform over *two* grid
axes multiplies the feasible fft parallelism while each individual
all_to_all still moves dims divisible by its own (small) axis size.
``choose_dft_grid`` encodes that ladder so benchmarks, examples and
services don't each hand-roll mesh shapes:

  * few devices relative to the sphere diameter → 1D fft grid (one
    transpose, biggest messages);
  * more devices → (batch, fft) 2D grid with the largest fft factor that
    keeps per-device pencils thick, the rest of the machine on the batch
    axis — provided the band count divides it, and preferring splits whose
    batch factor also carries the ``nk·nbands`` *stacked* batch;
  * when a (batch, fft, fft) **pencil** split reaches strictly more fft
    parallelism than any single fft axis can (``pf1·pf2`` devices on the
    transforms instead of ``pf``), the 3-axis grid wins: each fft axis
    keeps the per-axis pencil rule ``pf_i · max_fft_fraction ≤ diameter``,
    the sphere dim carries both axes (so ``diameter % (pf1·pf2) == 0``),
    and the batch factor still divides ``nbands``.  Falling back: pencil →
    2D → 1D, with the same k-stackable preference at every tier.
"""
from __future__ import annotations

from repro.core import ProcGrid

#: default mesh-axis names for the DFT grids built here
DFT_AXES_3D = ("dft_b", "dft_f1", "dft_f2")
DFT_AXES_2D = ("dft_b", "dft_f")
DFT_AXES_1D = ("dft_f",)


def _fft_factors(diameter: int, max_fft_fraction: int) -> list[int]:
    """fft-axis sizes keeping per-device pencils ≥ max_fft_fraction lines."""
    return [f for f in range(diameter, 1, -1)
            if diameter % f == 0 and f * max_fft_fraction <= diameter]


def choose_dft_grid_shape(ndevices: int, *, nbands: int, diameter: int,
                          nk: int = 1,
                          max_fft_fraction: int = 4) -> tuple[int, ...]:
    """Pick a grid shape (1-, 2- or 3-tuple) for ``ndevices``.

    1D ``(ndevices,)`` while ``ndevices · max_fft_fraction ≤ diameter``
    (per-device pencils stay ≥ ``max_fft_fraction`` lines thick).  Beyond
    that, the 2D split ``(pb, pf)`` with the largest feasible fft factor
    ``pf`` (divides both ``ndevices`` and ``diameter``, keeps the pencil
    rule) whose batch factor ``pb = ndevices // pf`` divides ``nbands`` —
    the per-k sphere plans always batch exactly ``nbands`` bands, so this
    is a hard ``PlaneWaveBasis`` requirement.

    **Pencil tier**: when a 3-axis ``(pb, pf1, pf2)`` split puts strictly
    more devices on the transforms than the best single fft axis can
    (``pf1·pf2 > pf``), it wins.  Feasibility per candidate: each
    ``pf_i ≥ 2`` keeps the per-axis pencil rule
    ``pf_i · max_fft_fraction ≤ diameter`` and divides ``diameter``; the
    sphere dim is sharded over both axes on the input side, so
    ``diameter % (pf1·pf2) == 0``; and ``pb ≥ 2`` divides ``nbands``
    (a pencil split with ``pb == 1`` is never preferred over the 2D
    split — a second fft axis costs an extra all_to_all round, so it
    must buy parallelism the batch axis cannot).  Among candidates the
    largest ``pf1·pf2`` wins, squarer splits break ties.

    Among qualifying splits at every tier, one that satisfies the
    ``basis.stacks_k`` contract — ``nk | pb`` and ``pb | nk·nbands``, so
    the stacked nk·nbands Hamiltonian/density batch shards evenly — is
    preferred (it engages the batched band-update engine).  The
    degradation ladder when the preferences cannot be met: a qualifying
    split whose ``pb`` the k-point count does not divide still wins over
    the next tier down (the basis then runs the pipelined per-k fallback
    on it, ``stacks_k`` False — though segmented stacking often restores
    the stacked route anyway), and when no split divides at all — prime
    device counts, ``nbands`` smaller than or coprime to every feasible
    ``pb`` — the chooser falls back to ``(ndevices,)`` (the basis's own
    divisibility checks then produce the actionable error).
    """
    if ndevices < 1:
        raise ValueError(f"ndevices must be >= 1, got {ndevices}")
    if ndevices == 1 or ndevices * max_fft_fraction <= diameter:
        return (ndevices,)
    fft_cands = [f for f in range(ndevices, 0, -1)
                 if ndevices % f == 0 and diameter % f == 0
                 and f * max_fft_fraction <= diameter]
    valid: list[tuple[int, int]] = []
    best_pf = 0
    for pf in fft_cands:
        pb = ndevices // pf
        if pb == 1:
            return (pf,)                # whole machine fits on one fft axis
        if nbands % pb == 0:
            valid.append((pb, pf))
            best_pf = max(best_pf, pf)

    # pencil tier: (pb, pf1, pf2) beating the best single-axis fft factor
    pencil: list[tuple[int, int, int]] = []
    axis_cands = _fft_factors(diameter, max_fft_fraction)
    for pf1 in axis_cands:
        for pf2 in (f for f in axis_cands if f <= pf1):
            prod = pf1 * pf2
            if prod <= best_pf:
                continue                # no more fft parallelism than 2D
            if ndevices % prod or diameter % prod:
                continue                # sphere dim carries both axes
            pb = ndevices // prod
            if pb < 2 or nbands % pb:
                continue
            pencil.append((pb, pf1, pf2))
    # largest fft coverage first; squarer split (larger minor axis) on ties
    pencil.sort(key=lambda s: (-(s[1] * s[2]), -s[2]))
    for pb, pf1, pf2 in pencil:         # prefer k-stackable batch axes
        if nk > 1 and pb % nk == 0:
            return (pb, pf1, pf2)
    if pencil:
        return pencil[0]

    for pb, pf in valid:                # prefer k-stackable batch axes:
        # nk | pb puts whole k-points on each shard; pb | nk·nbands (the
        # stacked H/density batch) already follows from pb | nbands above,
        # so this is the full basis.stacks_k contract
        if nk > 1 and pb % nk == 0:
            return (pb, pf)
    if valid:
        return valid[0]
    return (ndevices,)


def choose_dft_grid(ndevices: int | None = None, *, nbands: int,
                    diameter: int, nk: int = 1,
                    max_fft_fraction: int = 4) -> ProcGrid:
    """Build the ProcGrid ``choose_dft_grid_shape`` picks."""
    import jax
    nd = int(ndevices) if ndevices is not None else jax.device_count()
    shape = choose_dft_grid_shape(nd, nbands=nbands, diameter=diameter,
                                  nk=nk, max_fft_fraction=max_fft_fraction)
    names = {1: DFT_AXES_1D, 2: DFT_AXES_2D, 3: DFT_AXES_3D}[len(shape)]
    return ProcGrid.create(list(shape), list(names))
