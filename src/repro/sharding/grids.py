"""Process-grid selection for the DFT workload: 1D fft-only vs 2D batch×fft.

The paper's §3.3 argument: once the fft axes saturate what the sphere
diameter can absorb (an all_to_all needs the moved dim divisible by the
axis size, and message sizes shrink linearly with it), the *batch*
dimension — bands, and k-points stacked with them — is the axis that keeps
scaling.  ``choose_dft_grid`` encodes that rule of thumb so benchmarks,
examples and services don't each hand-roll mesh shapes:

  * few devices relative to the sphere diameter → 1D fft grid (one
    transpose, biggest messages);
  * more devices → (batch, fft) 2D grid with the largest fft factor that
    keeps per-device pencils thick, the rest of the machine on the batch
    axis — provided the band count divides it, and preferring splits whose
    batch factor also carries the ``nk·nbands`` *stacked* batch (since the
    Hamiltonian apply and the density build both ride one ragged
    k-stacked transform when ``basis.stacks_k``, a k-stackable batch axis
    is worth more than a marginally larger fft factor).
"""
from __future__ import annotations

from repro.core import ProcGrid

#: default mesh-axis names for the DFT grids built here
DFT_AXES_2D = ("dft_b", "dft_f")
DFT_AXES_1D = ("dft_f",)


def choose_dft_grid_shape(ndevices: int, *, nbands: int, diameter: int,
                          nk: int = 1,
                          max_fft_fraction: int = 4) -> tuple[int, ...]:
    """Pick a grid shape (1- or 2-tuple) for ``ndevices``.

    1D ``(ndevices,)`` while ``ndevices · max_fft_fraction ≤ diameter``
    (per-device pencils stay ≥ ``max_fft_fraction`` lines thick).  Beyond
    that, the 2D split ``(pb, pf)`` with the largest feasible fft factor
    ``pf`` (divides both ``ndevices`` and ``diameter``, keeps the pencil
    rule) whose batch factor ``pb = ndevices // pf`` divides ``nbands`` —
    the per-k sphere plans always batch exactly ``nbands`` bands, so this
    is a hard ``PlaneWaveBasis`` requirement.  Among qualifying splits,
    one that satisfies the full ``basis.stacks_k`` contract — ``nk | pb``
    and ``pb | nk·nbands``, so the stacked nk·nbands Hamiltonian/density
    batch shards evenly — is preferred (it engages the batched band-update
    engine: the whole sweep becomes two distributed transforms plus a
    handful of batched XLA calls).  The degradation ladder when the
    preferences cannot be met: a qualifying split whose ``pb`` the
    k-point count does not divide still wins over 1D (the basis then runs
    the pipelined per-k fallback on it, ``stacks_k`` False), and when no
    split divides at all — prime device counts, ``nbands`` smaller than
    or coprime to every feasible ``pb`` — the chooser falls back to
    ``(ndevices,)`` (the basis's own divisibility checks then produce
    the actionable error).
    """
    if ndevices < 1:
        raise ValueError(f"ndevices must be >= 1, got {ndevices}")
    if ndevices == 1 or ndevices * max_fft_fraction <= diameter:
        return (ndevices,)
    fft_cands = [f for f in range(ndevices, 0, -1)
                 if ndevices % f == 0 and diameter % f == 0
                 and f * max_fft_fraction <= diameter]
    valid: list[tuple[int, int]] = []
    for pf in fft_cands:
        pb = ndevices // pf
        if pb == 1:
            return (pf,)                  # whole machine fits on fft axes
        if nbands % pb == 0:
            valid.append((pb, pf))
    for pb, pf in valid:                  # prefer k-stackable batch axes:
        # nk | pb puts whole k-points on each shard; pb | nk·nbands (the
        # stacked H/density batch) already follows from pb | nbands above,
        # so this is the full basis.stacks_k contract
        if nk > 1 and pb % nk == 0:
            return (pb, pf)
    if valid:
        return valid[0]
    return (ndevices,)


def choose_dft_grid(ndevices: int | None = None, *, nbands: int,
                    diameter: int, nk: int = 1,
                    max_fft_fraction: int = 4) -> ProcGrid:
    """Build the ProcGrid ``choose_dft_grid_shape`` picks."""
    import jax
    nd = int(ndevices) if ndevices is not None else jax.device_count()
    shape = choose_dft_grid_shape(nd, nbands=nbands, diameter=diameter,
                                  nk=nk, max_fft_fraction=max_fft_fraction)
    names = DFT_AXES_2D if len(shape) == 2 else DFT_AXES_1D
    return ProcGrid.create(list(shape), list(names))
