"""The paper's own workload: batched plane-wave FFT, 256³ grid, sphere
diameter 128, 256 bands (Fig. 9 red line) — dry-run + hillclimb target."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PlaneWaveConfig:
    name: str = "fftb-paper"
    n: int = 256           # FFT grid width
    diameter: int = 128    # cut-off sphere diameter (= n/2, Fig. 2)
    nb: int = 256          # bands (batch)
    backend: str = "matmul"


CONFIG = PlaneWaveConfig()
