"""granite-moe-3b-a800m — MoE 40 experts top-8, d_ff(expert)=512.

NOTE: the assignment line also says "32 experts top-8" in its comment; we
implement the structured field (40e) — recorded in DESIGN.md §5.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv=8, d_ff=512,
    vocab=49155, n_experts=40, top_k=8, activation="swiglu",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
