"""nemotron-4-340b — dense GQA kv=8, squared-ReLU [arXiv:2402.16819]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv=8, d_ff=73728,
    vocab=256000, activation="relu2",
    source="arXiv:2402.16819; unverified",
))
