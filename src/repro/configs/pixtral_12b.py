"""pixtral-12b — VLM: pixtral-ViT frontend (STUB: precomputed patch
embeddings per task spec) + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=14336,
    vocab=131072, activation="swiglu", n_img_tokens=1024,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
))
