"""qwen3-32b — dense, GQA kv=8, qk-norm [hf:Qwen/Qwen3-8B; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv=8, d_ff=25600,
    vocab=151936, qk_norm=True, activation="swiglu", rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B; hf",
))
