"""whisper-small — encoder-decoder; conv audio frontend STUBBED (the model
consumes precomputed frame embeddings per task spec) [arXiv:2212.04356]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072,
    vocab=51865, activation="geglu", enc_layers=12, enc_seq=1500,
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
))
