"""Architecture/shape configuration system and registry.

Every assigned architecture lives in its own ``configs/<id>.py`` holding the
exact published config; ``reduced()`` derives the CPU-smoke-test version of
the same family.  Shapes are the four assigned (seq_len × global_batch)
cells; ``applicable()`` encodes the long_500k sub-quadratic rule.
"""
from __future__ import annotations

import dataclasses
import importlib
import math


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 → d_model // n_heads
    activation: str = "swiglu"       # swiglu | relu2 | geglu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    conv_kernel: int = 4
    conv_impl: str = "direct"        # direct | fft  (fft → FFTB fft_conv)
    # --- hybrid (RecurrentGemma) ---
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    local_window: int = 0
    d_rnn: int = 0                   # RG-LRU width (0 → d_model)
    # --- encoder-decoder (Whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0                 # precomputed frame embeddings (stub)
    # --- VLM (Pixtral) ---
    n_img_tokens: int = 0            # precomputed patch embeddings (stub)
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    # ----------------------------------------------------------- derived
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Analytic parameter count (total; for MoE also see active)."""
        D, F, V, L, H, K = (self.d_model, self.d_ff, self.vocab,
                            self.n_layers, self.n_heads, self.n_kv)
        hd = self.head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        per = 0
        if self.family == "ssm":
            din, ns, nh = self.d_inner, self.ssm_state, self.ssm_nheads
            per = (D * (2 * din + 2 * ns + nh)      # in_proj (x,z,B,C,dt)
                   + self.conv_kernel * (din + 2 * ns)
                   + din * D + 3 * nh)              # out_proj, A/D/dt_bias
            return emb + L * per + D
        attn = D * H * hd + 2 * D * K * hd + H * hd * D
        glu = self.activation in ("swiglu", "geglu")
        mlp = D * F * (3 if glu else 2)
        if self.family == "moe":
            mlp = self.n_experts * D * self.d_ff * (3 if glu else 2) \
                + D * self.n_experts
        if self.family == "hybrid":
            drnn = self.d_rnn or D
            rec = 2 * D * drnn + drnn * D + self.conv_kernel * drnn \
                + 2 * drnn * drnn + 2 * drnn
            n_attn = sum(1 for i in range(L)
                         if self.block_pattern[i % len(self.block_pattern)]
                         == "attn")
            n_rec = L - n_attn
            return emb + n_attn * (attn + mlp + 2 * D) \
                + n_rec * (rec + mlp + 2 * D) + D
        layers = L * (attn + mlp + 2 * D)
        if self.family == "encdec":
            layers += self.enc_layers * (attn + mlp + 2 * D) \
                + L * (attn + D)            # cross-attn in decoder
        return emb + layers + D

    def active_param_count(self) -> int:
        if self.family != "moe":
            return self.param_count()
        dense_like = dataclasses.replace(
            self, family="dense", d_ff=self.d_ff * self.top_k)
        return dense_like.param_count() + \
            self.n_layers * self.d_model * self.n_experts

    # ------------------------------------------------------------ reduced
    def reduced(self) -> "ArchConfig":
        """Same family, tiny: for CPU smoke tests (fwd + train step)."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 if not self.block_pattern
                         else len(self.block_pattern)),
            d_model=64,
            n_heads=4,
            n_kv=min(max(self.n_kv, 1), 2) if self.n_kv else 0,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            local_window=min(self.local_window, 32),
            d_rnn=64 if self.d_rnn else 0,
            enc_layers=min(self.enc_layers, 2),
            enc_seq=min(self.enc_seq, 16),
            n_img_tokens=min(self.n_img_tokens, 8),
            dtype="float32",
            remat="none",
        )


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # train | prefill | decode
    seq: int             # sequence length (decode: KV-cache length)
    batch: int           # global batch


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

ARCH_IDS = [
    "qwen3-32b", "tinyllama-1.1b", "nemotron-4-340b", "granite-3-2b",
    "pixtral-12b", "granite-moe-3b-a800m", "dbrx-132b", "whisper-small",
    "recurrentgemma-9b", "mamba2-370m",
]

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    for a in ARCH_IDS:
        get_config(a)
    return dict(_REGISTRY)


def applicable(cfg: ArchConfig, shape: Shape) -> tuple[bool, str]:
    """Is this (arch × shape) cell runnable-by-design?"""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("O(S²) full attention at 524k — long-context decode "
                       "runs only for sub-quadratic (ssm/hybrid) archs")
    return True, ""
