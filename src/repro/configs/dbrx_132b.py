"""dbrx-132b — fine-grained MoE 16 experts top-4 [hf:databricks/dbrx-base]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv=8, d_ff=10752,
    vocab=100352, n_experts=16, top_k=4, activation="swiglu",
    source="hf:databricks/dbrx-base; unverified",
))
