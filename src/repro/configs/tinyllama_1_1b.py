"""tinyllama-1.1b — llama2-arch small, GQA kv=4 [arXiv:2401.02385; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv=4, d_ff=5632,
    vocab=32000, activation="swiglu",
    source="arXiv:2401.02385; hf",
))
