"""mamba2-370m — attention-free SSD (state-space duality) [arXiv:2405.21060].

The depthwise temporal conv can run through FFTB (`conv_impl="fft"`) — the
paper-technique integration point for this family (DESIGN.md §5).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_headdim=64,
    conv_kernel=4, conv_impl="direct",
    source="arXiv:2405.21060; unverified",
))
