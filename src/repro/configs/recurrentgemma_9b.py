"""recurrentgemma-9b — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288,
    vocab=256000, activation="geglu",
    block_pattern=("rec", "rec", "attn"), local_window=2048, d_rnn=4096,
    source="arXiv:2402.19427; unverified",
))
