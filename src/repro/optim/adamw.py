"""AdamW with cosine schedule, global-norm clipping, ZeRO-sharded states.

States are plain pytrees mirroring the params, so `sharding.rules.param_specs`
applies verbatim → m/v are FSDP+TP sharded exactly like their weights
(ZeRO-3-style).  Master weights stay in the params dtype (f32 by default in
this framework; bf16 casting happens at compute time inside the models).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init_state(params, dtype=jnp.float32) -> dict:
    """dtype=bfloat16 gives memory-reduced (8-bit-Adam-style) states —
    the standard lever for ≥100B-param models on 16 GB/chip meshes."""
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                       # decoupled decay on matrices
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
