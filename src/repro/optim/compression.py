"""Gradient compression with error feedback, for cross-pod all-reduce.

At 1000+ nodes the pod-crossing gradient reduction is DCN-bound; int8
per-tensor quantization cuts it 4× vs f32 (2× vs bf16).  Error feedback
(residual accumulation) keeps SGD-style convergence: the quantization error
of step t is added back to the gradient of step t+1, so the *accumulated*
update is unbiased.

compress/decompress are pure and jit-able; the trainer threads the residual
state alongside the optimizer state (sharded identically to the grads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, residuals):
    """→ (compressed pytree of (int8, scale), new_residuals).

    The compressed representation is what crosses the pod boundary; the
    residual keeps the information lost to quantization for the next step.
    """
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize(x)
        err = x - _dequantize(q, s)
        return (q, s), err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = tdef.unflatten([o[0] for o in outs])
    res = tdef.unflatten([o[1] for o in outs])
    return comp, res


def decompress_grads(comp):
    def is_pair(x):
        return isinstance(x, tuple) and len(x) == 2 and \
            getattr(x[0], "dtype", None) == jnp.int8
    return jax.tree.map(lambda qs: _dequantize(*qs), comp,
                        is_leaf=is_pair)


def compressed_bytes(grads) -> int:
    """Bytes crossing the wire with int8 compression (for the comm model)."""
    return sum(x.size + 4 for x in jax.tree.leaves(grads))
