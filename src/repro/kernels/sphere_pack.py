"""Fused sphere-pack Pallas kernels for the plane-wave hot path.

The Hamiltonian hot chain ``pack(F(v_eff · F⁻¹(unpack(c))))`` pays two full
``(B, d, d, d)`` bounding-cube materializations per sweep: ``unpack``
scatters packed CSR coefficients into a freshly-zeroed cube that the first
line-DFT stage immediately re-reads, and ``pack`` gathers npacked lanes back
out of a cube the last stage just wrote.  The CMU flexible-DFT framework
(1904.10119) argues the data-layout permutation should be fused *into* the
line-transform GEMM; these two kernels realize that in Pallas:

``unpack_dft``
    reads packed CSR lanes directly and applies the first rectangular
    (d→n, pad-fused) line-DFT stage per bounding-box line, writing the
    first-stage slab ``(B, ex, ey, n)`` without materializing the cube.
    The grid walks x-planes; a per-plane support flag lets planes whose
    lines are all outside the sphere cross-section skip the gather *and*
    the GEMM and write zeros straight from the accumulator.

``dft_pack``
    fuses the final truncating (n→d) line-DFT stage with the CSR gather
    back to ``(B, npacked)``.  Padded lanes of a ragged stacked batch are
    masked to exact zeros — the PR 4 validity contract (padded lanes come
    out +0.0 whatever the slab holds) is preserved bitwise.

Both kernels use the same split re/im four-GEMM formulation as
``dft_matmul._kernel`` (one ``dot_general`` per product, f32 accumulation,
contraction over the full line) so on CPU ``interpret=True`` they are
*bitwise* equal to the XLA matmul route — the correctness oracle the
stacked-vs-per-k harness gates.

Index tables are static numpy built at plan time (`line_tables` /
`pack_gather_tables`), CSR-by-xy per ``SphereDomain.pack_indices``: packed
lanes of one (x, y) line are contiguous with z ascending, so a line is
``(start, z_lo, cnt)`` and the in-kernel gather is ``start + (z − z_lo)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.obs.metrics import global_metrics

_INTERPRET = jax.default_backend() != "tpu"

#: process-wide fused-kernel dispatch counts (python dispatch level: under
#: jit each traced call site counts once, like ``FftPlan.executions``) —
#: lets the bench gate assert the pallas route actually ran.
DISPATCHES = {"unpack_dft": 0, "dft_pack": 0}

global_metrics().register_probe("sphere_pack", lambda: dict(DISPATCHES))


def _reset_dispatches():          # test helper
    for k in DISPATCHES:
        DISPATCHES[k] = 0


# --------------------------------------------------------------- tables
def line_tables(spheres, nbands: int):
    """Static per-row line tables for the fused unpack-DFT kernel.

    For every sphere k and bounding-box line l = x·ey + y:
    ``start[k, l]`` — CSR lane of the line's first packed coefficient,
    ``zlo[k, l]`` — its z offset inside the box, ``cnt[k, l]`` — the line's
    packed length (0 outside the sphere's xy projection).  Tables are
    row-expanded to the stacked batch (row b belongs to sphere b // nbands)
    so the kernel needs no second indirection.  ``flag[x]`` is 1 iff *any*
    sphere has support in x-plane x — the kernel's zero-skip predicate must
    be conservative across the whole stacked batch.

    Returns ``(start, zlo, cnt, flag)``: three ``(len(spheres)·nbands, ex·ey)``
    int32 tables and an ``(ex, 1)`` int32 flag column.
    """
    spheres = list(spheres)
    if not spheres:
        raise ValueError("line_tables needs at least one sphere")
    ex, ey, ez = spheres[0].extents
    nlines = ex * ey
    nk = len(spheres)
    start = np.zeros((nk, nlines), np.int32)
    zlo = np.zeros((nk, nlines), np.int32)
    cnt = np.zeros((nk, nlines), np.int32)
    flag = np.zeros((ex, 1), np.int32)
    for k, s in enumerate(spheres):
        if s.extents != (ex, ey, ez):
            raise ValueError(f"sphere batch must share one bounding box; "
                             f"got {s.extents} vs {(ex, ey, ez)}")
        flat = s.pack_indices()
        lines = flat // ez
        # CSR order is line-major (columns ascend in (x, y)) with z
        # contiguous ascending inside each line
        uniq, first, counts = np.unique(lines, return_index=True,
                                        return_counts=True)
        start[k, uniq] = first
        zlo[k, uniq] = flat[first] % ez
        cnt[k, uniq] = counts
        flag[uniq // ey] = 1
    rep = functools.partial(np.repeat, repeats=nbands, axis=0)
    return rep(start), rep(zlo), rep(cnt), flag


def pack_gather_tables(spheres, nbands: int, npacked_max: int | None = None):
    """Static per-row gather tables for the fused DFT-pack kernel.

    Per padded lane p of sphere k: the bounding-box line ``line[k, p]`` and
    z offset ``z[k, p]`` the lane reads from, plus ``valid[k, p]`` (0 on
    padding — the kernel masks those lanes to exact zero).  Row-expanded to
    the stacked batch like :func:`line_tables`.
    """
    spheres = list(spheres)
    if not spheres:
        raise ValueError("pack_gather_tables needs at least one sphere")
    ez = spheres[0].extents[2]
    if npacked_max is None:
        npacked_max = max(s.npacked for s in spheres)
    nk = len(spheres)
    line = np.zeros((nk, npacked_max), np.int32)
    zz = np.zeros((nk, npacked_max), np.int32)
    valid = np.zeros((nk, npacked_max), np.int32)
    for k, s in enumerate(spheres):
        flat = s.pack_indices()
        line[k, :s.npacked] = flat // ez
        zz[k, :s.npacked] = flat % ez
        valid[k, :s.npacked] = 1
    rep = functools.partial(np.repeat, repeats=nbands, axis=0)
    return rep(line), rep(zz), rep(valid)


# -------------------------------------------------------------- kernels
def _unpack_dft_kernel(flag_ref, start_ref, zlo_ref, cnt_ref, pr_ref, pi_ref,
                       wr_ref, wi_ref, yr_ref, yi_ref):
    """One x-plane: gather its ey packed lines, apply the d→n line DFT."""
    n, d = wr_ref.shape

    @pl.when(flag_ref[0, 0] == 0)
    def _skip():
        # no sphere support anywhere in this plane: the oracle's GEMM over
        # all-zero lines yields exact +0.0 — write it without the FLOPs
        yr_ref[...] = jnp.zeros(yr_ref.shape, yr_ref.dtype)
        yi_ref[...] = jnp.zeros(yi_ref.shape, yi_ref.dtype)

    @pl.when(flag_ref[0, 0] != 0)
    def _compute():
        start = start_ref[...]
        zlo = zlo_ref[...]
        cnt = cnt_ref[...]
        B, bl = start.shape
        npk = pr_ref.shape[1]
        z = jax.lax.broadcasted_iota(jnp.int32, (B, bl, d), 2)
        sel = (z >= zlo[:, :, None]) & (z < (zlo + cnt)[:, :, None])
        idx = jnp.clip(start[:, :, None] + (z - zlo[:, :, None]),
                       0, npk - 1).reshape(B, bl * d)
        xr = jnp.where(sel, jnp.take_along_axis(pr_ref[...], idx,
                                                axis=1).reshape(B, bl, d),
                       0.0).reshape(B * bl, d)
        xi = jnp.where(sel, jnp.take_along_axis(pi_ref[...], idx,
                                                axis=1).reshape(B, bl, d),
                       0.0).reshape(B * bl, d)
        wr = wr_ref[...]
        wi = wi_ref[...]
        f32 = jnp.float32
        dn = (((1,), (1,)), ((), ()))
        rr = jax.lax.dot_general(xr, wr, dn, preferred_element_type=f32)
        ii = jax.lax.dot_general(xi, wi, dn, preferred_element_type=f32)
        ri = jax.lax.dot_general(xr, wi, dn, preferred_element_type=f32)
        ir = jax.lax.dot_general(xi, wr, dn, preferred_element_type=f32)
        yr_ref[...] = (rr - ii).reshape(B, 1, bl, n).astype(yr_ref.dtype)
        yi_ref[...] = (ri + ir).reshape(B, 1, bl, n).astype(yi_ref.dtype)


def _dft_pack_kernel(xr_ref, xi_ref, wr_ref, wi_ref, g_ref, v_ref,
                     pr_ref, pi_ref):
    """Truncating n→d line DFT over the whole local slab + CSR gather."""
    B, ex, ey, n = xr_ref.shape
    d = wr_ref.shape[0]
    nlines = ex * ey
    xr = xr_ref[...].reshape(B * nlines, n)
    xi = xi_ref[...].reshape(B * nlines, n)
    wr = wr_ref[...]
    wi = wi_ref[...]
    f32 = jnp.float32
    dn = (((1,), (1,)), ((), ()))
    rr = jax.lax.dot_general(xr, wr, dn, preferred_element_type=f32)
    ii = jax.lax.dot_general(xi, wi, dn, preferred_element_type=f32)
    ri = jax.lax.dot_general(xr, wi, dn, preferred_element_type=f32)
    ir = jax.lax.dot_general(xi, wr, dn, preferred_element_type=f32)
    yr = (rr - ii).reshape(B, nlines * d)
    yi = (ri + ir).reshape(B, nlines * d)
    g = g_ref[...]
    v = v_ref[...] != 0
    pr_ref[...] = jnp.where(v, jnp.take_along_axis(yr, g, axis=1),
                            0.0).astype(pr_ref.dtype)
    pi_ref[...] = jnp.where(v, jnp.take_along_axis(yi, g, axis=1),
                            0.0).astype(pi_ref.dtype)


# ------------------------------------------------------------- wrappers
@functools.partial(jax.jit, static_argnames=("interpret",))
def unpack_dft(pr, pi, start, zlo, cnt, flag, wr, wi, *,
               interpret: bool | None = None):
    """Fused CSR-unpack + first-stage line DFT.

    ``pr``/``pi``: (B, npacked) packed f32 planes; ``start``/``zlo``/``cnt``:
    (B, ex·ey) per-row line tables; ``flag``: (ex, 1) plane-support column;
    ``wr``/``wi``: (n, d) rectangular DFT factor.  Returns the first-stage
    slab as (B, ex, ey, n) f32 re/im planes — the zero-padded cube is never
    materialized.
    """
    interpret = _INTERPRET if interpret is None else interpret
    B, npk = pr.shape
    ex = flag.shape[0]
    ey = start.shape[1] // ex
    n, d = wr.shape
    p_spec = pl.BlockSpec((B, npk), lambda i: (0, 0))
    t_spec = pl.BlockSpec((B, ey), lambda i: (0, i))
    w_spec = pl.BlockSpec((n, d), lambda i: (0, 0))
    y_spec = pl.BlockSpec((B, 1, ey, n), lambda i: (0, i, 0, 0))
    return pl.pallas_call(
        _unpack_dft_kernel,
        grid=(ex,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (i, 0)),
                  t_spec, t_spec, t_spec, p_spec, p_spec, w_spec, w_spec],
        out_specs=[y_spec, y_spec],
        out_shape=[jax.ShapeDtypeStruct((B, ex, ey, n), jnp.float32)] * 2,
        interpret=interpret,
    )(flag, start, zlo, cnt, pr, pi, wr, wi)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dft_pack(xr, xi, g, valid, wr, wi, *, interpret: bool | None = None):
    """Fused final truncating line DFT + CSR pack gather.

    ``xr``/``xi``: (B, ex, ey, n) last-stage slab planes; ``g``: (B, npacked)
    gather indices into the per-row (ex·ey·d,) transformed lines; ``valid``:
    (B, npacked) int32 lane mask (0 → exact-zero output lane); ``wr``/``wi``:
    (d, n) truncating DFT factor.  Returns (B, npacked) packed f32 planes.
    """
    interpret = _INTERPRET if interpret is None else interpret
    B, ex, ey, n = xr.shape
    npk = g.shape[1]
    d = wr.shape[0]
    x_spec = pl.BlockSpec((B, ex, ey, n), lambda i: (0, 0, 0, 0))
    w_spec = pl.BlockSpec((d, n), lambda i: (0, 0))
    g_spec = pl.BlockSpec((B, npk), lambda i: (0, 0))
    return pl.pallas_call(
        _dft_pack_kernel,
        grid=(1,),
        in_specs=[x_spec, x_spec, w_spec, w_spec, g_spec, g_spec],
        out_specs=[g_spec, g_spec],
        out_shape=[jax.ShapeDtypeStruct((B, npk), jnp.float32)] * 2,
        interpret=interpret,
    )(xr, xi, wr, wi, g, valid)
