"""Pallas TPU kernel: batched (rectangular) complex DFT as MXU matmuls.

The paper's local-compute stage calls cuFFT; on TPU the right primitive for
line lengths in the plane-wave regime (n ≤ ~2k) is a dense DFT *matmul* on
the 128×128 MXU — O(n²) FLOPs at 197 TFLOP/s beat O(n log n) VPU shuffles,
and the rectangular slice of the DFT matrix fuses the sphere zero-pad /
truncation for free (DESIGN.md §2).

Complex arithmetic is split re/im (the MXU has no complex type): one kernel
invocation performs the four real GEMMs

    yr = xr·Wrᵀ − xi·Wiᵀ          yi = xr·Wiᵀ + xi·Wrᵀ

with an optional fused twiddle epilogue (used by the four-step large-n
factorization): y ← y ⊙ (tr + i·ti), where t broadcasts over rows.

Tiling: grid (B/bm, N/bn); x blocks (bm, K) stream down the batch, W blocks
(bn, K) stream across output frequencies, K (= n_in ≤ 2048) is kept whole in
VMEM — worst case VMEM footprint ≈ 2·bm·K + 2·bn·K + 2·bm·bn floats ≈ 6.5 MB
at (bm, bn, K) = (256, 128, 2048), comfortably inside the ~16 MB budget,
with MXU-aligned (multiple-of-128) contraction and output dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    f32 = jnp.float32
    # 4 real GEMMs on the MXU; accumulate in f32 regardless of input dtype
    rr = jax.lax.dot_general(xr, wr, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    ii = jax.lax.dot_general(xi, wi, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    ri = jax.lax.dot_general(xr, wi, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    ir = jax.lax.dot_general(xi, wr, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    yr_ref[...] = (rr - ii).astype(yr_ref.dtype)
    yi_ref[...] = (ri + ir).astype(yi_ref.dtype)


def _kernel_twiddle(xr_ref, xi_ref, wr_ref, wi_ref, tr_ref, ti_ref,
                    yr_ref, yi_ref):
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    f32 = jnp.float32
    rr = jax.lax.dot_general(xr, wr, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    ii = jax.lax.dot_general(xi, wi, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    ri = jax.lax.dot_general(xr, wi, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    ir = jax.lax.dot_general(xi, wr, (((1,), (1,)), ((), ())),
                             preferred_element_type=f32)
    yr = rr - ii
    yi = ri + ir
    tr = tr_ref[...]            # (bm, bn): per-row twiddles, pre-broadcast
    ti = ti_ref[...]
    yr_ref[...] = (yr * tr - yi * ti).astype(yr_ref.dtype)
    yi_ref[...] = (yr * ti + yi * tr).astype(yi_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "interpret"))
def dft_matmul(xr, xi, wr, wi, tr=None, ti=None, *, bm: int = 256,
               bn: int = 128, interpret: bool = False):
    """y = (xr + i·xi) @ (wr + i·wi)ᵀ [⊙ twiddle], shapes (B,K)·(N,K)→(B,N).

    B must be divisible by bm and N by bn (ops.py pads).  ``tr``/``ti`` are
    optional (B, N) twiddle factors fused into the epilogue.
    """
    B, K = xr.shape
    N = wr.shape[0]
    assert B % bm == 0 and N % bn == 0, (B, N, bm, bn)
    grid = (B // bm, N // bn)
    x_spec = pl.BlockSpec((bm, K), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((bn, K), lambda i, j: (j, 0))
    y_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out_shape = [jax.ShapeDtypeStruct((B, N), xr.dtype)] * 2
    if tr is None:
        return pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[x_spec, x_spec, w_spec, w_spec],
            out_specs=[y_spec, y_spec],
            out_shape=out_shape,
            interpret=interpret,
        )(xr, xi, wr, wi)
    t_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel_twiddle,
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec, t_spec, t_spec],
        out_specs=[y_spec, y_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi, tr, ti)
