"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.local_fft import dft_matrix


def dft_apply_ref(x, n_out: int | None = None, *, inverse: bool = False):
    """Oracle for kernels.ops.dft_apply: (B, n_in) complex → (B, n_out).

    Defined via jnp.fft on the zero-padded / truncated line so the oracle is
    independent of the DFT-matrix construction used by the kernel.
    """
    b, n_in = x.shape
    n_out = n_in if n_out is None else n_out
    fn = jnp.fft.ifft if inverse else jnp.fft.fft
    if n_in <= n_out:
        xp = jnp.pad(x, ((0, 0), (0, n_out - n_in)))
        return fn(xp, axis=-1)
    return fn(x, axis=-1)[:, :n_out]


def complex_matmul_ref(xr, xi, wr, wi):
    """Oracle for the raw kernel: y = x @ w.T in split re/im form."""
    yr = xr @ wr.T - xi @ wi.T
    yi = xr @ wi.T + xi @ wr.T
    return yr, yi


def four_step_ref(x, *, inverse: bool = False):
    """Oracle for kernels.ops.four_step_dft — plain jnp.fft."""
    fn = jnp.fft.ifft if inverse else jnp.fft.fft
    return fn(x, axis=-1)


def twiddle_matrix(n1: int, n2: int, inverse: bool) -> np.ndarray:
    """W_N^{j1·k2} twiddles for the four-step split N = n1·n2.

    Convention (kernels/ops.py): input line reshaped to (n2, n1) with j1
    fast; inner DFT_n2 over axis 0 → T[k2, j1]; T *= W[k2, j1]; outer DFT_n1
    over axis 1 → Z[k2, k1]; output = Z.T.ravel().
    """
    n = n1 * n2
    j1 = np.arange(n1)
    k2 = np.arange(n2)
    sign = 2j if inverse else -2j
    w = np.exp(sign * np.pi * np.outer(k2, j1) / n)
    return w.astype(np.complex64)
