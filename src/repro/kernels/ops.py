"""jit'd wrappers around the Pallas DFT kernel + the four-step composition.

On CPU (this container) the kernels run with ``interpret=True``; on TPU the
same code emits real Mosaic kernels.  ``dft_apply`` handles padding of the
batch/frequency dims to the kernel tile sizes; ``four_step_dft`` factors
large n into two MXU-sized stages with the twiddle fused into the first
stage's epilogue.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.local_fft import dft_matrix
from . import ref as _ref
from .dft_matmul import dft_matmul

_INTERPRET = jax.default_backend() != "tpu"


def _pad_to(x, m: int, axis: int):
    n = x.shape[axis]
    r = (-n) % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad)


def _pick_block(n: int, pref: int) -> int:
    """Largest MXU-friendly block ≤ pref that keeps padding mild."""
    if n >= pref:
        return pref
    # small problems: round up to the 8-lane sublane granule
    return max(8, 1 << (n - 1).bit_length())


def dft_apply(x, n_out: int | None = None, *, inverse: bool = False,
              bm: int = 256, bn: int = 128,
              interpret: bool | None = None):
    """Batched line DFT via the Pallas kernel: (B, n_in) → (B, n_out).

    Rectangular n_in≠n_out fuses zero-padding (n_in < n_out) or spectrum
    truncation (n_in > n_out) into the GEMM shape.
    """
    interpret = _INTERPRET if interpret is None else interpret
    B, n_in = x.shape
    n_out = n_in if n_out is None else n_out
    w = dft_matrix(n_out, n_in, inverse)
    wr = jnp.asarray(w.real)
    wi = jnp.asarray(w.imag)
    xr = jnp.real(x).astype(jnp.float32)
    xi = jnp.imag(x).astype(jnp.float32)

    bm = _pick_block(B, bm)
    bn = _pick_block(n_out, bn)
    xr = _pad_to(xr, bm, 0)
    xi = _pad_to(xi, bm, 0)
    wr = _pad_to(wr, bn, 0)
    wi = _pad_to(wi, bn, 0)
    yr, yi = dft_matmul(xr, xi, wr, wi, bm=bm, bn=bn, interpret=interpret)
    return jax.lax.complex(yr[:B, :n_out], yi[:B, :n_out])


@functools.lru_cache(maxsize=64)
def _factor(n: int) -> tuple[int, int]:
    """n = n1·n2 with n1 ≈ n2 (n1 the outer/output-major factor)."""
    best = (1, n)
    for n1 in range(2, int(math.isqrt(n)) + 1):
        if n % n1 == 0:
            best = (n1, n // n1)
    n1, n2 = best
    if n1 == 1:
        raise ValueError(f"four-step needs composite n, got prime {n}")
    return n1, n2


def four_step_dft(x, *, inverse: bool = False, interpret: bool | None = None):
    """Large-n line DFT: two MXU-sized stages + fused twiddle (Bailey).

    x: (B, n) with composite n = n1·n2.  Stage 1: DFT_n2 over columns with
    the W_N^{j1·k2} twiddle fused into the kernel epilogue; stage 2: DFT_n1
    over rows; output re-rolled to natural order.
    """
    interpret = _INTERPRET if interpret is None else interpret
    B, n = x.shape
    n1, n2 = _factor(n)
    # (B, n) -> (B, n2, n1): j = j1 + n1·j2, j1 fast
    xm = x.reshape(B, n2, n1)

    # --- stage 1: DFT_n2 along axis 1, twiddle fused -------------------
    # lines are the n1 columns: bring them to rows: (B, n1, n2)
    s1 = jnp.swapaxes(xm, 1, 2).reshape(B * n1, n2)
    tw = _ref.twiddle_matrix(n1, n2, inverse)            # (n2, n1)
    w = dft_matrix(n2, n2, inverse)
    wr, wi = jnp.asarray(w.real), jnp.asarray(w.imag)
    # twiddle for row (b, j1): t[k2] = tw[k2, j1] — build (B·n1, n2)
    twt = jnp.asarray(np.ascontiguousarray(tw.T))        # (n1, n2)
    tr = jnp.tile(jnp.real(twt), (B, 1))
    ti = jnp.tile(jnp.imag(twt), (B, 1))
    xr = jnp.real(s1).astype(jnp.float32)
    xi = jnp.imag(s1).astype(jnp.float32)
    bm = _pick_block(B * n1, 256)
    bn = _pick_block(n2, 128)
    xr = _pad_to(xr, bm, 0)
    xi = _pad_to(xi, bm, 0)
    wrp = _pad_to(wr, bn, 0)
    wip = _pad_to(wi, bn, 0)
    trp = _pad_to(_pad_to(tr, bm, 0), bn, 1)
    tip = _pad_to(_pad_to(ti, bm, 0), bn, 1)
    yr, yi = dft_matmul(xr, xi, wrp, wip, trp, tip, bm=bm, bn=bn,
                        interpret=interpret)
    t = jax.lax.complex(yr[:B * n1, :n2], yi[:B * n1, :n2])  # (B·n1, n2)

    # --- stage 2: DFT_n1 along j1 ---------------------------------------
    z = t.reshape(B, n1, n2)
    z = jnp.swapaxes(z, 1, 2).reshape(B * n2, n1)            # rows: k2
    z = dft_apply(z, inverse=inverse, interpret=interpret)   # (B·n2, n1)
    # output order k = k2 + n2·k1 → (B, k1, k2) ravel
    y = z.reshape(B, n2, n1)
    y = jnp.swapaxes(y, 1, 2).reshape(B, n)
    if inverse:
        # both stages applied 1/n2 and 1/n1 → already 1/n total
        pass
    return y
