"""Low-overhead span tracer with Chrome-trace/Perfetto export.

One process-global :class:`Tracer` (``get_tracer()``) records *complete*
spans — named wall-clock intervals with nesting tracked per thread — into
a bounded ring buffer.  The design constraints, in order:

* **Disabled is free.**  ``tracer.span(...)`` on a disabled tracer returns
  a shared no-op singleton: no span object is allocated, no lock is taken,
  no timestamp is read.  Instrumented hot paths guard on
  ``tracer.enabled`` (a plain attribute) before building attribute dicts.
* **Honest device timing.**  JAX dispatch is asynchronous — a span that
  closes right after ``fn(x)`` times the *dispatch*, not the execution.
  ``span.sync(out)`` marks a value to ``jax.block_until_ready`` at span
  exit (when ``tracer.sync`` is on), so the recorded duration covers the
  device work the span claims to measure.
* **Threads nest independently.**  Each thread has its own span stack;
  depth and parent are per-thread, and exported events carry a per-thread
  track id so Perfetto renders one lane per thread.

Export is the Chrome trace event format (``ph: "X"`` complete events,
timestamps in microseconds) — load the JSON in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque


def timed_call(fn, *args, **kwargs):
    """``(result, seconds)`` of ``fn(*args)`` with the device drained.

    The one honest way to wall-clock a JAX call: the clock stops only
    after ``jax.block_until_ready(result)``, so asynchronous dispatch
    cannot make the call look faster than the device work it launched.
    Benchmark loops and tuners should route through this (or replicate
    its block-before-stop pattern) — timing ``fn(x)`` bare measures
    dispatch latency, not execution.
    """
    import jax
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args, **kwargs))
    return out, time.perf_counter() - t0


class _NoopSpan:
    """Shared do-nothing span — the disabled tracer's fast path.

    A singleton: tests assert ``tracer.span('a') is tracer.span('b')``
    to pin the no-allocation property.
    """

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def sync(self, value):
        return value


NOOP_SPAN = _NoopSpan()


class Span:
    """One live span: a context manager that records itself on exit."""

    __slots__ = ("_tracer", "name", "attrs", "t0", "t1", "depth", "parent",
                 "_sync_value", "_tid")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = self.t1 = None
        self.depth = 0
        self.parent = None
        self._sync_value = None
        self._tid = None

    def set(self, **attrs):
        """Attach attributes after entry (e.g. results known at exit)."""
        self.attrs.update(attrs)
        return self

    def sync(self, value):
        """Mark ``value`` for ``block_until_ready`` at span exit.

        Returns ``value`` so call sites can write
        ``out = sp.sync(fn(x))``.  No-op when ``tracer.sync`` is off.
        """
        self._sync_value = value
        return value

    def __enter__(self):
        stack = self._tracer._stack()
        self.depth = len(stack)
        self.parent = stack[-1].name if stack else None
        self._tid = threading.get_ident()
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._sync_value is not None and self._tracer.sync:
            import jax
            jax.block_until_ready(self._sync_value)
            self._sync_value = None
        self.t1 = time.perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record(self.name, self.t0, self.t1, self._tid,
                             self.depth, self.parent, self.attrs)
        return False


class Tracer:
    """Bounded recorder of spans; export via :meth:`to_chrome`."""

    def __init__(self, max_events: int = 200_000):
        self.enabled = False
        self.sync = True          # block_until_ready at span exit
        self.per_stage = True     # plans execute stage-by-stage when traced
        self._events: deque = deque(maxlen=max_events)
        self.dropped = 0
        self._local = threading.local()
        self._lock = threading.Lock()
        self._origin = time.perf_counter()

    # ------------------------------------------------------------ lifecycle
    def enable(self, *, sync: bool = True, per_stage: bool = True,
               clear: bool = True) -> "Tracer":
        """Start recording.  ``sync`` blocks on marked values at span exit
        (honest device timing); ``per_stage`` asks plans to execute
        stage-by-stage so FFT vs all_to_all get separate spans."""
        if clear:
            self.clear()
        self.sync = bool(sync)
        self.per_stage = bool(per_stage)
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0
            self._origin = time.perf_counter()

    # ------------------------------------------------------------ recording
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, **attrs):
        """A context manager timing the enclosed block (no-op singleton
        when disabled — guard attribute construction on ``enabled`` if
        the attrs themselves are expensive)."""
        if not self.enabled:
            return NOOP_SPAN
        return Span(self, name, attrs)

    def event(self, name: str, t0: float, t1: float, **attrs) -> None:
        """Record a complete event with explicit ``perf_counter`` bounds.

        For intervals that span threads (queue wait: submitted on a
        tenant thread, resolved on the dispatch thread) where a context
        manager cannot bracket the work.
        """
        if not self.enabled:
            return
        self._record(name, t0, t1, threading.get_ident(), 0, None, attrs)

    def instant(self, name: str, **attrs) -> None:
        """Record a zero-duration marker (cache miss, eviction, ...)."""
        if not self.enabled:
            return
        t = time.perf_counter()
        self._record(name, t, t, threading.get_ident(), 0, None, attrs)

    def _record(self, name, t0, t1, tid, depth, parent, attrs) -> None:
        ev = {"name": name, "t0": t0, "t1": t1, "tid": tid,
              "depth": depth, "parent": parent, "attrs": attrs}
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    # -------------------------------------------------------------- queries
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def summary(self) -> dict:
        """Per-name {count, total_ms} rollup of the recorded spans."""
        out: dict[str, dict] = {}
        for ev in self.events():
            s = out.setdefault(ev["name"], {"count": 0, "total_ms": 0.0})
            s["count"] += 1
            s["total_ms"] += (ev["t1"] - ev["t0"]) * 1e3
        for s in out.values():
            s["total_ms"] = round(s["total_ms"], 3)
        return out

    # --------------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """The trace as a Chrome trace event object (Perfetto-loadable).

        Complete (``ph: "X"``) events with microsecond timestamps
        relative to the last ``clear()``; one track per thread (small
        sequential tids plus thread-name metadata events).
        """
        events = self.events()
        pid = os.getpid()
        tids: dict[int, int] = {}
        out = []
        for ev in events:
            tid = tids.setdefault(ev["tid"], len(tids))
            args = {k: v for k, v in ev["attrs"].items()}
            if ev["parent"] is not None:
                args["parent"] = ev["parent"]
            args["depth"] = ev["depth"]
            out.append({
                "name": ev["name"], "cat": "repro", "ph": "X",
                "ts": (ev["t0"] - self._origin) * 1e6,
                "dur": max((ev["t1"] - ev["t0"]) * 1e6, 0.0),
                "pid": pid, "tid": tid, "args": args,
            })
        meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": t,
                 "args": {"name": f"thread-{t}"}} for t in tids.values()]
        return {"traceEvents": meta + out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export_chrome(self, path: str) -> str:
        """Write :meth:`to_chrome` JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=_jsonable)
            f.write("\n")
        return path


def _jsonable(x):
    """Fallback serializer: numpy scalars → python, else str()."""
    try:
        return x.item()
    except AttributeError:
        return str(x)


_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    """The process-global tracer every instrumented layer records into."""
    return _GLOBAL
