"""Process-global metrics registry: counters, gauges, histograms, probes.

The repo grew instrumentation ad hoc — ``FftPlan.executions`` (a class
counter), ``PERK_LINALG_CALLS``, ``PlanCache.stats``, the transform
service's latency percentiles — each with its own shape and no single
place to read them.  :class:`MetricsRegistry` is that place:

* ``counter(name)`` / ``gauge(name)`` / ``histogram(name)`` — named
  instruments, created on first use, thread-safe.
* ``register_probe(name, fn)`` — a callback snapshotting *existing*
  state, so the legacy counters re-register onto the registry without
  changing their back-compatible APIs: ``core.plan`` registers an
  ``fftb`` probe over its class counters, ``core.cache`` a
  ``plan_cache`` probe over the global cache's ``stats``,
  ``dft.hamiltonian`` a ``dft`` probe, and each ``ServiceMetrics``
  (weakly) a ``serve`` probe over its ``summary()``.
* ``snapshot()`` — one JSON-serializable dict of everything, embedded
  into schema-4 bench records so ``compare.py`` can attribute a
  throughput regression to a phase (plan builds?  cache churn?  comm?).

Histograms keep a bounded :class:`Reservoir` (ring buffer) of recent
samples — long-running services must not grow memory without bound — and
their percentile math is defined on empty (→ 0.0) and single-sample
windows (→ that sample).
"""
from __future__ import annotations

import math
import threading
import weakref
from collections import deque


def percentile(samples, q: float) -> float:
    """Linear-interpolated percentile, safe on empty/single windows.

    ``[] → 0.0``; one sample → that sample; otherwise the usual
    linear interpolation between closest ranks (numpy's default
    method, without requiring numpy).
    """
    xs = sorted(float(v) for v in samples)
    if not xs:
        return 0.0
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * (q / 100.0)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


class Reservoir:
    """Bounded sample window: a ring buffer plus a total count.

    ``record`` is O(1); once ``maxlen`` samples are held the oldest is
    dropped, so percentiles reflect the recent window while ``count``
    keeps the all-time total (request counts must not be capped by the
    sample bound).
    """

    __slots__ = ("_buf", "count")

    def __init__(self, maxlen: int = 2048):
        if maxlen < 1:
            raise ValueError("maxlen must be >= 1")
        self._buf: deque = deque(maxlen=int(maxlen))
        self.count = 0

    def record(self, value: float) -> None:
        self._buf.append(float(value))
        self.count += 1

    def values(self) -> list[float]:
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def maxlen(self) -> int:
        return self._buf.maxlen

    def percentile(self, q: float) -> float:
        return percentile(self._buf, q)

    def mean(self) -> float:
        return sum(self._buf) / len(self._buf) if self._buf else 0.0

    def max(self) -> float:
        return max(self._buf) if self._buf else 0.0


class Counter:
    """Monotonic named count (thread-safe)."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins named value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded distribution of samples; summary is window percentiles."""

    __slots__ = ("_lock", "_res")

    def __init__(self, window: int = 2048):
        self._lock = threading.Lock()
        self._res = Reservoir(window)

    def record(self, value: float) -> None:
        with self._lock:
            self._res.record(value)

    @property
    def count(self) -> int:
        return self._res.count

    def summary(self) -> dict:
        with self._lock:
            return {
                "count": self._res.count,
                "window": len(self._res),
                "mean": round(self._res.mean(), 6),
                "p50": round(self._res.percentile(50), 6),
                "p99": round(self._res.percentile(99), 6),
                "max": round(self._res.max(), 6),
            }


class MetricsRegistry:
    """Named instruments + probes, snapshotted as one dict."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._probes: dict = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str, window: int = 2048) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(window)
            return h

    def register_probe(self, name: str, fn) -> None:
        """Register ``fn() -> dict`` snapshotted under ``name``.

        Re-registering replaces (module reloads, newest service wins).
        A probe that raises contributes ``{"error": ...}`` instead of
        breaking the snapshot.
        """
        with self._lock:
            self._probes[name] = fn

    def unregister_probe(self, name: str) -> None:
        with self._lock:
            self._probes.pop(name, None)

    def reset(self) -> None:
        """Drop every instrument (probes stay registered — they read
        external state the registry does not own)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def snapshot(self) -> dict:
        """Everything, as one JSON-serializable dict."""
        with self._lock:
            counters = {k: v.value for k, v in self._counters.items()}
            gauges = {k: v.value for k, v in self._gauges.items()}
            hists = {k: v.summary() for k, v in self._histograms.items()}
            probes = dict(self._probes)
        out = {"counters": counters, "gauges": gauges,
               "histograms": hists}
        for name, fn in probes.items():
            try:
                val = fn()
            except Exception as err:   # a broken probe must not break obs
                val = {"error": repr(err)}
            if val is not None:
                out[name] = _plain(val)
        return out


def _plain(x):
    """Recursively coerce to JSON-serializable python scalars."""
    if isinstance(x, dict):
        return {str(k): _plain(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_plain(v) for v in x]
    if isinstance(x, (str, bool, int, float)) or x is None:
        return x
    try:
        return x.item()                        # numpy scalar
    except AttributeError:
        return str(x)


def diff_snapshot(before: dict, after: dict) -> dict:
    """``after − before`` on numeric leaves; non-numeric keep ``after``.

    The per-scenario window the bench harness embeds: counters are
    process-cumulative, so a scenario's contribution is the delta across
    its run.  Keys only in ``after`` pass through unchanged.
    """
    out = {}
    for k, av in after.items():
        bv = before.get(k)
        if isinstance(av, dict) and isinstance(bv, dict):
            out[k] = diff_snapshot(bv, av)
        elif (isinstance(av, (int, float)) and not isinstance(av, bool)
              and isinstance(bv, (int, float)) and not isinstance(bv, bool)):
            out[k] = av - bv
        else:
            out[k] = av
    return out


def register_weak_probe(registry: MetricsRegistry, name: str, obj,
                        method: str = "summary") -> None:
    """Probe ``getattr(obj, method)()`` without keeping ``obj`` alive.

    Long-lived registries must not pin short-lived services: the probe
    holds a weakref and reports ``None`` (dropped from snapshots) after
    the object is collected.
    """
    ref = weakref.ref(obj)

    def probe():
        target = ref()
        return None if target is None else getattr(target, method)()

    registry.register_probe(name, probe)


_GLOBAL = MetricsRegistry()


def global_metrics() -> MetricsRegistry:
    """The process-global registry bench records snapshot."""
    return _GLOBAL
