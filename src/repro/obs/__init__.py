"""Observability: span tracing + metrics registry (process-global).

``get_tracer()`` and ``global_metrics()`` are the two entry points; see
``obs/trace.py`` and ``obs/metrics.py``.  This package imports nothing
from the rest of ``repro`` — every layer (core, dft, serve, benchmarks)
records *into* it, never the other way around.
"""

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      Reservoir, diff_snapshot, global_metrics,
                      percentile, register_weak_probe)
from .trace import NOOP_SPAN, Span, Tracer, get_tracer, timed_call

__all__ = [
    "Tracer", "Span", "NOOP_SPAN", "get_tracer", "timed_call",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "Reservoir",
    "global_metrics", "percentile", "diff_snapshot",
    "register_weak_probe",
]
