"""The one structured finding type every ``repro.check`` analyzer emits.

A :class:`Diagnostic` is a stable machine-readable record: a code like
``FFTB110`` (grep-able, never renumbered), a severity, a human message, a
source location (``file:line`` for the linter) or config path (``scf-3d:
nbands`` for preflight) and a fix hint.  Exceptions raised by the library
boundary carry their diagnostics as :class:`DiagnosticError` — a
``ValueError`` subclass, so existing ``except ValueError`` / message-substring
handling keeps working while new callers can switch on ``err.code``.

``CODES`` is the registry the README table and ``python -m repro.check
codes`` render; adding a rule means adding one entry here.
"""
from __future__ import annotations

import dataclasses

#: severity levels, ordered: errors gate, warnings inform
Severity = str
ERROR: Severity = "error"
WARNING: Severity = "warning"

#: code -> one-line description; the single registry behind the README
#: table, the CLI ``codes`` subcommand and the analyzers' self-checks.
CODES: dict[str, str] = {
    # ---------------------------------------------- preflight (FFTB1xx)
    "FFTB101": "transform spec does not parse (bad token, duplicate dim, "
               "missing/extra '->', no transformed dims)",
    "FFTB102": "spec distribution tag references a grid axis the grid "
               "does not have",
    "FFTB103": "spec rank does not match the declared domains' rank",
    "FFTB110": "FFT cube width does not divide over the fft-axis process "
               "count",
    "FFTB111": "sphere bounding-box extents do not divide over the "
               "fft-axis process count",
    "FFTB112": "band count not divisible by the batch-axis process count",
    "FFTB113": "batch/fft grid axes invalid (overlapping, out of range, "
               "or no fft axis)",
    "FFTB114": "k-stacking preconditions not met — the stacked route "
               "falls back to per-k dispatch",
    "FFTB115": "segment sizes violate the batch-axis size_divisor "
               "contract",
    "FFTB116": "sphere diameter outside (0, n]",
    "FFTB117": "padding budget outside [0, 1)",
    "FFTB118": "pallas backend request violates the fused sphere-pack "
               "kernels' line-length or VMEM constraints",
    "FFTB120": "coefficient array shape does not match the sphere's "
               "packed length",
    "FFTB121": "dtype contract violation (complex coefficients / real "
               "potential expected)",
    "FFTB122": "request band count exceeds the service's max_rows",
    "FFTB130": "plan would not fit the plan-cache byte budget",
    # --------------------------------------------------- lint (FFTB2xx)
    "FFTB201": "host-sync call inside a traced function (reachable from "
               "jit_step / a jitted stage executor)",
    "FFTB202": "plan construction / PlanCache build inside a traced "
               "function (use the eager-fetch-at-trace-time pattern)",
    "FFTB203": "time.time() used for interval timing (use "
               "time.perf_counter())",
    "FFTB204": "wall-clock window around device dispatch without a "
               "block_until_ready/sync before the clock stops",
    "FFTB205": "bare threading.Lock/RLock on the serving path (use "
               "repro.check.locks.TrackedLock)",
    # -------------------------------------------------- locks (FFTB3xx)
    "FFTB301": "lock-order cycle: locks acquired in inconsistent order "
               "across threads",
    "FFTB302": "tracked lock held across a device-dispatch boundary",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One analyzer finding.

    ``location`` is ``"path:line"`` for source findings and a config
    path (``"scenario.nbands"``) for preflight findings; ``hint`` says
    how to fix it, not just what is wrong.
    """

    code: str
    severity: Severity
    message: str
    location: str = ""
    hint: str = ""

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unregistered diagnostic code {self.code!r}")
        if self.severity not in (ERROR, WARNING):
            raise ValueError(f"bad severity {self.severity!r}")

    @property
    def is_error(self) -> bool:
        return self.severity == ERROR

    def render(self) -> str:
        loc = f"{self.location}: " if self.location else ""
        hint = f"  [{self.hint}]" if self.hint else ""
        return f"{loc}{self.code} {self.severity}: {self.message}{hint}"


def error(code: str, message: str, *, location: str = "",
          hint: str = "") -> Diagnostic:
    return Diagnostic(code, ERROR, message, location, hint)


def warning(code: str, message: str, *, location: str = "",
            hint: str = "") -> Diagnostic:
    return Diagnostic(code, WARNING, message, location, hint)


def render_diagnostics(diags) -> str:
    """Multi-line rendering, errors before warnings, stable within."""
    diags = sorted(diags, key=lambda d: (not d.is_error,))
    return "\n".join(d.render() for d in diags)


class DiagnosticError(ValueError):
    """A ``ValueError`` carrying the structured diagnostics behind it.

    The library boundary raises this instead of bare ``ValueError``: the
    message keeps the historical human-readable text (existing handlers
    matching on substrings keep passing), while ``.diagnostics`` /
    ``.code`` expose the machine-readable findings.
    """

    def __init__(self, diagnostics):
        if isinstance(diagnostics, Diagnostic):
            diagnostics = [diagnostics]
        self.diagnostics = list(diagnostics)
        if not self.diagnostics:
            raise ValueError("DiagnosticError needs at least one diagnostic")
        super().__init__("; ".join(
            f"[{d.code}] {d.message}" for d in self.diagnostics))

    @property
    def code(self) -> str:
        """The first (most severe) diagnostic's code."""
        return self.diagnostics[0].code


def raise_if_errors(diags) -> list[Diagnostic]:
    """Raise :class:`DiagnosticError` on any error-severity diagnostic.

    Returns the diagnostics (warnings included) otherwise, so call sites
    can log them.
    """
    diags = list(diags)
    errors = [d for d in diags if d.is_error]
    if errors:
        raise DiagnosticError(errors)
    return diags
