"""Repo-invariant AST linter — machine-checked versions of the rules
reviewers have been enforcing by hand since PR 5/PR 7.

Rules (all ``FFTB2xx``, suppressible per line with ``# noqa: FFTB2xx``):

* **FFTB201** — host-sync calls (``float(<call>)``, ``np.asarray``,
  ``.block_until_ready()``, ``.item()``) inside a function reachable
  from a *traced root*: a ``@jax.jit``-decorated function, a function
  passed to ``jax.jit(...)`` / ``shard_map(...)``, or a name listed in
  ``TRACED_ROOTS``.  A host sync under tracing either fails outright or
  silently severs the fused graph.
* **FFTB202** — plan construction (``PlanCache.get_or_build``,
  ``fftb.plan_for``, the basis plan getters) inside a traced function.
  Plans must be fetched eagerly at trace time (the PR 5 pattern: fetch
  before ``jax.jit``, close over the results).
* **FFTB203** — ``time.time()`` used for *interval* timing (two reads,
  or subtracting a ``time.time()``-assigned variable).  Wall-clock
  intervals use ``time.perf_counter()``; a single ``time.time()`` epoch
  stamp (checkpoint metadata) is fine.
* **FFTB204** — a ``perf_counter`` timing window around jax/jnp compute
  with no sync marker (``block_until_ready`` / ``timed_call`` /
  ``np.asarray`` / ``.sync``) in the function: the interval would
  measure dispatch, not execution (the PR 7 honest-clock rule).
* **FFTB205** — a bare ``threading.Lock()``/``RLock()`` in ``serve/``
  or ``core/cache.py``: the serving path must use
  ``repro.check.locks.TrackedLock`` so lock-order checking can see it.

The linter is stdlib-only (``ast``) — it never imports the modules it
checks, so ``python -m repro.check lint src/`` runs without jax.
Reachability is a same-module call graph over simple names
(``foo(...)``, ``self.foo(...)``); cross-module reachability is
approximated by ``TRACED_ROOTS`` naming the known traced entry points.
"""
from __future__ import annotations

import ast
import pathlib
import re

from .diagnostics import Diagnostic, error

__all__ = ["lint_paths", "lint_source", "TRACED_ROOTS"]

#: function names treated as traced roots in *any* module, covering the
#: traced surfaces the AST alone cannot see (methods invoked from jitted
#: stage executors built in another module).
TRACED_ROOTS: frozenset = frozenset({
    "jit_step",
    "_execute_traced",
    "_raw_apply",
    "_raw_apply_lazy",
})

#: plan-construction entry points (FFTB202)
_PLAN_BUILDERS = frozenset({
    "get_or_build", "plan_for", "plans_for_k", "cube_plans",
    "stacked_inverse_plan", "stacked_hamiltonian_plans",
    "stacked_band_tables", "make_planewave_pair",
    "make_stacked_planewave_pair",
})

#: files where FFTB205 applies (relative-path substring match)
_LOCK_SCOPE = ("serve/", "core/cache.py")
_LOCK_EXEMPT = ("check/locks.py",)

_NOQA = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?", re.I)


# ----------------------------------------------------------- AST helpers
def _dotted(node) -> str:
    """'jax.jit' for Attribute chains, 'jit' for Names, '' otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _call_name(call: ast.Call) -> str:
    return _dotted(call.func)


def _call_attr(call: ast.Call) -> str:
    """The method/function name of a call, even on a call-result chain
    (``jnp.fft.fftn(x).block_until_ready()`` → ``block_until_ready``)."""
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return _attr_of(_call_name(call))


def _root_of(dotted: str) -> str:
    return dotted.split(".", 1)[0]


def _attr_of(dotted: str) -> str:
    return dotted.rsplit(".", 1)[-1]


_JIT_WRAPPERS = ("jax.jit", "jit", "partial")
_SHARD_WRAPPERS = ("shard_map", "compat.shard_map", "jax_shard_map")


def _is_jit_call(call: ast.Call) -> bool:
    name = _call_name(call)
    if name in ("jax.jit", "jit"):
        return True
    # functools.partial(jax.jit, ...) applied as a decorator
    if _attr_of(name) == "partial" and call.args:
        return _call_name_of_expr(call.args[0]) in ("jax.jit", "jit")
    return False


def _call_name_of_expr(node) -> str:
    return _dotted(node)


class _FnInfo:
    __slots__ = ("node", "name", "calls", "refs", "is_root")

    def __init__(self, node: ast.AST, name: str):
        self.node = node
        self.name = name
        self.calls: set[str] = set()
        self.refs: set[str] = set()
        self.is_root = False


def _own_statements(fn) -> list[ast.AST]:
    """The function's body nodes, with nested function bodies cut out.

    Nested defs are separate _FnInfo entries; their *names* still count
    as references from the enclosing function.
    """
    out: list[ast.AST] = []
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        out.append(node)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)
    return out


class _ModuleIndex:
    """All function defs in one module + the traced-reachability set."""

    def __init__(self, tree: ast.Module, extra_roots=()):
        self.fns: list[_FnInfo] = []
        self._by_name: dict[str, list[_FnInfo]] = {}
        roots = TRACED_ROOTS | frozenset(extra_roots)
        self._collect(tree)
        for fn in self.fns:
            node = fn.node
            if fn.name in roots:
                fn.is_root = True
            for dec in getattr(node, "decorator_list", ()):
                name = (_call_name(dec) if isinstance(dec, ast.Call)
                        else _dotted(dec))
                if name in ("jax.jit", "jit") or (
                        isinstance(dec, ast.Call) and _is_jit_call(dec)):
                    fn.is_root = True
        # functions passed (by name) to jit / shard_map become roots
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            is_wrapper = (name in ("jax.jit", "jit")
                          or _attr_of(name) in [_attr_of(w) for w
                                                in _SHARD_WRAPPERS])
            if not is_wrapper:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    for fn in self._by_name.get(arg.id, ()):
                        fn.is_root = True
        # call/reference edges
        for fn in self.fns:
            for stmt in _own_statements(fn.node):
                if isinstance(stmt, ast.Call):
                    callee = _attr_of(_call_name(stmt))
                    if callee:
                        fn.calls.add(callee)
                elif isinstance(stmt, ast.Name):
                    fn.refs.add(stmt.id)

    def _collect(self, tree) -> None:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = _FnInfo(node, node.name)
                self.fns.append(info)
                self._by_name.setdefault(node.name, []).append(info)

    def traced(self) -> set:
        """The set of _FnInfo reachable from any traced root."""
        reached: set[_FnInfo] = set()
        frontier = [fn for fn in self.fns if fn.is_root]
        while frontier:
            fn = frontier.pop()
            if fn in reached:
                continue
            reached.add(fn)
            for name in fn.calls | fn.refs:
                for nxt in self._by_name.get(name, ()):
                    if nxt not in reached:
                        frontier.append(nxt)
        return reached


# ----------------------------------------------------------------- rules
def _noqa_codes(line: str) -> set[str] | None:
    """Codes suppressed on this line; empty set = bare ``# noqa``."""
    m = _NOQA.search(line)
    if not m:
        return None
    codes = m.group("codes")
    if not codes:
        return set()
    return {c.strip().upper() for c in codes.split(",") if c.strip()}


def _suppressed(lines: list[str], lineno: int, code: str) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    codes = _noqa_codes(lines[lineno - 1])
    if codes is None:
        return False
    return not codes or code in codes


def _rule_host_sync(fn: _FnInfo, path: str, lines) -> list[Diagnostic]:
    out = []
    for node in _own_statements(fn.node):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        attr = _call_attr(node)
        bad = ""
        if name == "float" and node.args and isinstance(
                node.args[0], ast.Call):
            bad = "float(<device value>)"
        elif attr in ("block_until_ready", "item"):
            bad = f".{attr}()"
        elif attr == "asarray" and _root_of(name) in ("np", "numpy"):
            bad = "np.asarray"
        if bad and not _suppressed(lines, node.lineno, "FFTB201"):
            out.append(error(
                "FFTB201",
                f"host sync {bad} in {fn.name!r}, which is reachable "
                "from a traced root",
                location=f"{path}:{node.lineno}",
                hint="move the sync outside the jitted/shard_mapped "
                     "region, or use jnp ops on device values"))
    return out


def _rule_plan_build(fn: _FnInfo, path: str, lines) -> list[Diagnostic]:
    out = []
    for node in _own_statements(fn.node):
        if not isinstance(node, ast.Call):
            continue
        attr = _call_attr(node)
        if attr in _PLAN_BUILDERS and not _suppressed(
                lines, node.lineno, "FFTB202"):
            out.append(error(
                "FFTB202",
                f"plan construction {attr}(...) in {fn.name!r}, which "
                "is reachable from a traced root",
                location=f"{path}:{node.lineno}",
                hint="fetch plans eagerly before tracing and close "
                     "over them (the jit_step eager-fetch pattern)"))
    return out


def _rule_time_time(fn: _FnInfo, path: str, lines) -> list[Diagnostic]:
    calls: list[int] = []
    assigned: set[str] = set()
    subs: list[int] = []
    stmts = _own_statements(fn.node)
    for node in stmts:
        if isinstance(node, ast.Call) and _call_name(node) in (
                "time.time", "time"):
            if _call_name(node) == "time.time":
                calls.append(node.lineno)
        elif isinstance(node, ast.Assign) and isinstance(
                node.value, ast.Call) and _call_name(
                node.value) == "time.time":
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    assigned.add(tgt.id)
    for node in stmts:
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
            for side in (node.left, node.right):
                if isinstance(side, ast.Name) and side.id in assigned:
                    subs.append(node.lineno)
    flag_line = None
    if len(calls) >= 2:
        flag_line = calls[1]
    elif subs:
        flag_line = subs[0]
    if flag_line is None or _suppressed(lines, flag_line, "FFTB203"):
        return []
    return [error(
        "FFTB203",
        f"time.time() used for interval timing in {fn.name!r}",
        location=f"{path}:{flag_line}",
        hint="use time.perf_counter() for intervals; time.time() is "
             "for epoch stamps only")]


_SYNC_MARKERS = frozenset({"block_until_ready", "timed_call", "sync"})


def _rule_dispatch_clock(fn: _FnInfo, path: str, lines) -> list[Diagnostic]:
    pcs: list[int] = []
    has_compute = False
    has_sync = False
    for node in _own_statements(fn.node):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        attr = _call_attr(node)
        root = _root_of(name)
        if name == "time.perf_counter":
            pcs.append(node.lineno)
        elif attr in _SYNC_MARKERS:
            has_sync = True
        elif name == "float" or (attr == "asarray"
                                 and root in ("np", "numpy")):
            has_sync = True               # both materialize to host
        elif root in ("jax", "jnp", "lax") and attr not in (
                "jit", "asarray"):
            has_compute = True
    if len(pcs) < 2 or not has_compute or has_sync:
        return []
    if _suppressed(lines, pcs[-1], "FFTB204"):
        return []
    return [error(
        "FFTB204",
        f"perf_counter window around device compute in {fn.name!r} "
        "has no sync before the clock stops",
        location=f"{path}:{pcs[-1]}",
        hint="block_until_ready (or obs.timed_call / np.asarray) the "
             "result inside the window — otherwise the interval "
             "measures dispatch, not execution")]


def _rule_bare_lock(tree: ast.Module, path: str, lines) -> list[Diagnostic]:
    if not any(s in path for s in _LOCK_SCOPE) or any(
            s in path for s in _LOCK_EXEMPT):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name in ("threading.Lock", "threading.RLock", "Lock",
                    "RLock") and not _suppressed(
                lines, node.lineno, "FFTB205"):
            out.append(error(
                "FFTB205",
                f"bare {name}() on the serving path",
                location=f"{path}:{node.lineno}",
                hint="use repro.check.locks.TrackedLock so lock-order "
                     "checking can see this lock"))
    return out


# ------------------------------------------------------------ entry points
def lint_source(source: str, path: str = "<string>",
                extra_roots=()) -> list[Diagnostic]:
    """Lint one module's source text; returns diagnostics."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as err:
        return [error("FFTB201", f"cannot parse: {err}",
                      location=f"{path}:{err.lineno or 0}",
                      hint="fix the syntax error first")]
    lines = source.splitlines()
    index = _ModuleIndex(tree, extra_roots)
    traced = index.traced()
    diags: list[Diagnostic] = []
    for fn in index.fns:
        if fn in traced:
            diags.extend(_rule_host_sync(fn, path, lines))
            diags.extend(_rule_plan_build(fn, path, lines))
        diags.extend(_rule_time_time(fn, path, lines))
        diags.extend(_rule_dispatch_clock(fn, path, lines))
    diags.extend(_rule_bare_lock(tree, path, lines))
    return sorted(diags, key=lambda d: d.location)


def lint_paths(paths, extra_roots=()) -> list[Diagnostic]:
    """Lint every ``.py`` file under the given files/directories."""
    files: list[pathlib.Path] = []
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    diags: list[Diagnostic] = []
    for f in files:
        rel = f.as_posix()
        diags.extend(lint_source(f.read_text(), rel, extra_roots))
    return diags
