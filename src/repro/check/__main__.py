"""CLI for the static analyzers.

Usage::

    python -m repro.check lint src/ [more paths...]
    python -m repro.check preflight benchmarks/baseline.json
    python -m repro.check preflight config.json --scenario scf-3d
    python -m repro.check codes

``lint`` needs only the stdlib; ``preflight`` imports ``repro.core``
(but never touches devices — 8-device scenarios audit from any box).
Exit status: 0 clean, 1 on any error-severity diagnostic, 2 on usage
errors.  Warnings print but do not fail the run.
"""
from __future__ import annotations

import argparse
import json
import sys

from .diagnostics import CODES, render_diagnostics


def _cmd_lint(args) -> int:
    from .lint import lint_paths
    diags = lint_paths(args.paths, extra_roots=args.traced_root)
    if diags:
        print(render_diagnostics(diags))
    errors = [d for d in diags if d.is_error]
    print(f"repro.check lint: {len(errors)} error(s), "
          f"{len(diags) - len(errors)} warning(s)")
    return 1 if errors else 0


def _cmd_preflight(args) -> int:
    from .preflight import preflight_config, preflight_scenario
    with open(args.config) as fh:
        data = json.load(fh)
    diags = []
    if isinstance(data, dict) and "scenarios" in data:
        items = data["scenarios"].items()
        if args.scenario:
            missing = [s for s in args.scenario
                       if s not in data["scenarios"]]
            if missing:
                print(f"unknown scenario(s) {missing}; available: "
                      f"{sorted(data['scenarios'])}", file=sys.stderr)
                return 2
            items = [(s, data["scenarios"][s]) for s in args.scenario]
        for name, record in items:
            diags.extend(preflight_scenario(name, record))
        audited = len(list(items))
    else:
        diags.extend(preflight_config(data, name=args.config))
        audited = 1
    if diags:
        print(render_diagnostics(diags))
    errors = [d for d in diags if d.is_error]
    print(f"repro.check preflight: {audited} config(s) audited, "
          f"{len(errors)} error(s), {len(diags) - len(errors)} "
          "warning(s)")
    return 1 if errors else 0


def _cmd_codes(_args) -> int:
    width = max(len(c) for c in CODES)
    for code, desc in sorted(CODES.items()):
        print(f"{code:<{width}}  {desc}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="FFTB static analysis: preflight config "
                    "diagnostics, repo-invariant lint, diagnostic "
                    "code registry.")
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_lint = sub.add_parser("lint", help="AST-lint repo source")
    p_lint.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    p_lint.add_argument("--traced-root", action="append", default=[],
                        help="extra function name treated as a traced "
                             "root (repeatable)")
    p_lint.set_defaults(fn=_cmd_lint)

    p_pf = sub.add_parser(
        "preflight", help="audit a config / baseline scenario file")
    p_pf.add_argument("config",
                      help="JSON config dict or benchmarks baseline "
                           "file with a 'scenarios' table")
    p_pf.add_argument("--scenario", action="append", default=[],
                      help="audit only this scenario (repeatable)")
    p_pf.set_defaults(fn=_cmd_preflight)

    p_codes = sub.add_parser("codes", help="print the code registry")
    p_codes.set_defaults(fn=_cmd_codes)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
