"""``repro.check`` — static analysis for the FFTB repo and its configs.

Three coordinated analyzers, one :class:`~repro.check.diagnostics.Diagnostic`
currency:

* :mod:`repro.check.preflight` — feasibility diagnostics for transform
  specs, plane-wave bases and service configs *before any device work*:
  spec-DSL well-formedness, grid divisibility, stackability, dtype/shape
  contracts and plan-cache byte budgets, each with a stable ``FFTB1xx``
  code and a fix hint.  ``fftb.preflight(...)`` is the public alias.
* :mod:`repro.check.lint` — an AST linter for the repo's own invariants
  (``FFTB2xx``): no host syncs or plan builds inside traced functions,
  honest wall-clocks around device dispatch, no bare ``threading.Lock``
  on the serving path.
* :mod:`repro.check.locks` — an instrumented lock wrapper recording the
  per-thread held-lock graph; detects lock-order cycles and
  lock-held-across-dispatch hazards (``FFTB3xx``).  Free when disabled.

CLI: ``python -m repro.check {preflight,lint,codes} ...``.

``diagnostics`` and ``locks`` are import-light (stdlib only) so the core
and serve layers can depend on them; ``preflight`` pulls in
``repro.core`` and is loaded lazily (PEP 562) to keep the dependency
graph acyclic: core → check.locks/diagnostics, check.preflight → core.
"""

from .diagnostics import (CODES, Diagnostic, DiagnosticError, Severity,
                          render_diagnostics)
from .locks import (LockOrderError, TrackedLock, check_dispatch_hazard,
                    disable_lock_checking, enable_lock_checking,
                    lock_violations)

_PREFLIGHT_NAMES = ("preflight", "preflight_transform", "preflight_basis",
                    "preflight_service", "preflight_request")

__all__ = [
    "CODES", "Diagnostic", "DiagnosticError", "Severity",
    "render_diagnostics",
    "TrackedLock", "LockOrderError", "enable_lock_checking",
    "disable_lock_checking", "check_dispatch_hazard", "lock_violations",
    *_PREFLIGHT_NAMES,
]


def __getattr__(name: str):
    if name in _PREFLIGHT_NAMES:
        from . import preflight as _pf
        return getattr(_pf, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
