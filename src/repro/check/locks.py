"""Runtime lock-order checking for the serving path.

:class:`TrackedLock` wraps a ``threading.Lock``/``RLock`` and, when the
module-global monitor is enabled, records the per-thread held-lock stack
and a global acquired-while-holding order graph.  Two hazards are
detected the moment they are created:

* **FFTB301** — a lock-order cycle: thread A acquires ``x`` then ``y``
  while thread B (ever) acquired ``y`` then ``x``.  Reported when the
  second edge closes the cycle, without needing the actual deadlock to
  strike.
* **FFTB302** — a tracked lock held across a device-dispatch boundary:
  code calls :func:`check_dispatch_hazard` (placed at plan-build and
  service-dispatch sites) while holding any tracked lock, meaning a
  multi-second device operation would run under a lock other threads
  contend on.

Checking follows the observability layer's free-when-disabled pattern:
``_MONITOR`` is ``None`` by default, so the per-acquire overhead is a
single attribute load and ``is None`` test — no allocation, no
thread-local traffic.  Enable it in stress tests / CI with
:func:`enable_lock_checking`.

Violations either raise :class:`LockOrderError` immediately
(``mode="raise"``, the default for tests) or accumulate as
:class:`~repro.check.diagnostics.Diagnostic` records retrievable via
:func:`lock_violations` (``mode="record"``, usable in long-running
services).
"""
from __future__ import annotations

import threading

from .diagnostics import Diagnostic, error

__all__ = [
    "TrackedLock",
    "LockOrderError",
    "enable_lock_checking",
    "disable_lock_checking",
    "check_dispatch_hazard",
    "lock_violations",
]


class LockOrderError(RuntimeError):
    """Raised by the monitor in ``raise`` mode; carries the diagnostic."""

    def __init__(self, diagnostic: Diagnostic):
        self.diagnostic = diagnostic
        super().__init__(diagnostic.render())


class _Monitor:
    """Global lock-order state: order graph + per-thread held stacks."""

    def __init__(self, mode: str = "raise"):
        if mode not in ("raise", "record"):
            raise ValueError(f"bad lock-checking mode {mode!r}")
        self.mode = mode
        self._held = threading.local()
        # edges[a] = {b: "siteinfo"} meaning some thread acquired b while
        # holding a.  Guarded by _graph_lock (a plain lock: the monitor
        # is infrastructure, not a subject of its own analysis).
        self._edges: dict[str, dict[str, str]] = {}
        self._graph_lock = threading.Lock()
        self.violations: list[Diagnostic] = []

    # -- per-thread held stack ------------------------------------------
    def _stack(self) -> list[str]:
        st = getattr(self._held, "stack", None)
        if st is None:
            st = []
            self._held.stack = st
        return st

    # -- events ---------------------------------------------------------
    def on_acquire(self, name: str, *, reentrant: bool) -> None:
        stack = self._stack()
        if reentrant and name in stack:
            # RLock re-entry by the same thread: no new ordering edge.
            stack.append(name)
            return
        holder = stack[-1] if stack else None
        stack.append(name)
        if holder is None or holder == name:
            return
        with self._graph_lock:
            self._edges.setdefault(holder, {})[name] = (
                f"thread {threading.current_thread().name}")
            cycle = self._find_cycle(name, holder)
        if cycle:
            self._report(error(
                "FFTB301",
                "lock-order cycle: " + " -> ".join(cycle),
                location=f"acquiring {name!r} while holding {holder!r}",
                hint="acquire these locks in one global order, or drop "
                     "the outer lock before taking the inner one",
            ))

    def on_release(self, name: str) -> None:
        stack = self._stack()
        # Release in LIFO discipline is the common case; tolerate
        # out-of-order release (remove the innermost matching entry).
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                return

    def on_dispatch(self, tag: str) -> None:
        stack = self._stack()
        if stack:
            self._report(error(
                "FFTB302",
                f"device dispatch {tag!r} while holding lock(s) "
                f"{stack!r}",
                location=tag,
                hint="release the lock before building/dispatching; "
                     "PlanCache runs builders outside its lock for "
                     "exactly this reason",
            ))

    # -- graph ----------------------------------------------------------
    def _find_cycle(self, start: str, target: str):
        """Path start -> ... -> target in the edge graph (DFS), if any.

        Called with the new edge target->start already inserted, so a
        path start ->* target closes a cycle.  Caller holds _graph_lock.
        """
        seen = set()
        path = [start]

        def dfs(node: str):
            if node == target:
                return True
            seen.add(node)
            for nxt in self._edges.get(node, ()):
                if nxt in seen:
                    continue
                path.append(nxt)
                if dfs(nxt):
                    return True
                path.pop()
            return False

        if dfs(start):
            return [target, *path, target]
        return None

    def _report(self, diag: Diagnostic) -> None:
        self.violations.append(diag)
        if self.mode == "raise":
            raise LockOrderError(diag)


#: None when checking is disabled — the fast-path cost of TrackedLock is
#: then one global load and an `is None` test per acquire/release.
_MONITOR: _Monitor | None = None


def enable_lock_checking(mode: str = "raise") -> None:
    """Turn on lock-order checking process-wide (fresh state)."""
    global _MONITOR
    _MONITOR = _Monitor(mode)


def disable_lock_checking() -> None:
    global _MONITOR
    _MONITOR = None


def lock_violations() -> list[Diagnostic]:
    """Diagnostics recorded so far (``record`` mode); empty if disabled."""
    if _MONITOR is None:
        return []
    return list(_MONITOR.violations)


def check_dispatch_hazard(tag: str) -> None:
    """Assert no tracked lock is held at a device-dispatch boundary.

    Place this where multi-second device work starts (plan builds,
    batched dispatch).  Free when checking is disabled.
    """
    if _MONITOR is not None:
        _MONITOR.on_dispatch(tag)


class TrackedLock:
    """Drop-in ``threading.Lock``/``RLock`` that reports to the monitor.

    ``TrackedLock("plan_cache")`` is a plain lock;
    ``TrackedLock("plan_cache", reentrant=True)`` wraps an ``RLock``.
    Supports the context-manager protocol plus explicit
    ``acquire``/``release`` and ``locked`` like the stdlib types.
    """

    __slots__ = ("name", "reentrant", "_lock")

    def __init__(self, name: str, *, reentrant: bool = False):
        self.name = name
        self.reentrant = reentrant
        self._lock = threading.RLock() if reentrant else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        mon = _MONITOR
        if mon is not None:
            # Record intent before blocking: the edge (held -> this)
            # exists whether or not we end up waiting.
            try:
                mon.on_acquire(self.name, reentrant=self.reentrant)
            except LockOrderError:
                mon.on_release(self.name)
                raise
        ok = self._lock.acquire(blocking, timeout)
        if not ok and mon is not None:
            mon.on_release(self.name)
        return ok

    def release(self) -> None:
        self._lock.release()
        mon = _MONITOR
        if mon is not None:
            mon.on_release(self.name)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self) -> bool:
        lk = self._lock
        if hasattr(lk, "locked"):
            return lk.locked()
        # RLock pre-3.14 has no locked().  A non-blocking probe succeeds
        # when *this* thread owns the lock (reentrancy), so check
        # ownership first, then probe for other-thread holders.
        if getattr(lk, "_is_owned", lambda: False)():
            return True
        if lk.acquire(blocking=False):
            lk.release()
            return False
        return True

    def __repr__(self) -> str:
        kind = "RLock" if self.reentrant else "Lock"
        return f"TrackedLock({self.name!r}, {kind})"
