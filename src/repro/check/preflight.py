"""Preflight feasibility diagnostics — reject infeasible work *before*
any device work.

The paper's flexibility (arbitrary specs, non-regular sphere domains,
1D/2D/3D process grids) is exactly where configurations go wrong: an
indivisible extent or an over-tight cache budget otherwise surfaces as a
``shard_map`` explosion deep inside plan execution.  Every check here is
static host arithmetic over the declared configuration; each finding is
a :class:`~repro.check.diagnostics.Diagnostic` with a stable ``FFTB1xx``
code and a fix hint.

Entry points
------------
* :func:`preflight_transform` — an arrow spec against domains/grid:
  DSL well-formedness, grid-axis references, rank, sharded-extent
  divisibility.  ``fftb.plan_for`` runs this on every cache miss.
* :func:`preflight_basis` — a ``PlaneWaveBasis`` configuration, from a
  live :class:`~repro.core.grid.ProcGrid` **or** a bare ``grid_shape``
  tuple (so an 8-device scenario audits from a 1-device box).  With
  ``deep=True`` it also builds the k-point spheres host-side and checks
  segmentation, stackability and plan-cache byte feasibility.
* :func:`preflight_service` / :func:`preflight_request` — a
  ``TransformService`` configuration / one submit call.
* :func:`preflight` — the umbrella ``fftb.preflight``: a spec string
  routes to the transform checks, a config dict (e.g. one scenario from
  ``benchmarks/baseline.json``) to the basis/service checks.

All functions *return* the diagnostics list; they never raise.  Library
call sites wrap them in
:func:`~repro.check.diagnostics.raise_if_errors`.
"""
from __future__ import annotations

import math

from .diagnostics import Diagnostic, error, raise_if_errors, warning

__all__ = [
    "preflight",
    "preflight_transform",
    "preflight_basis",
    "preflight_service",
    "preflight_request",
    "preflight_config",
    "preflight_scenario",
]


# --------------------------------------------------------------- helpers
def _grid_shape(grid, grid_shape) -> tuple[int, ...] | None:
    if grid is not None:
        return tuple(grid.shape)
    if grid_shape is not None:
        return tuple(int(s) for s in grid_shape)
    return None


def _axes_split(shape, batch_axes, fft_axes, *, where: str
                ) -> tuple[tuple, tuple, int, int, list[Diagnostic]]:
    """Resolve (batch, fft) axes over ``shape`` with basis defaults."""
    ndim = len(shape)
    if batch_axes is None:
        batch_axes = () if ndim == 1 else (0,)
    batch_axes = tuple(batch_axes)
    if fft_axes is None:
        fft_axes = tuple(a for a in range(ndim) if a not in batch_axes)
    fft_axes = tuple(fft_axes)
    used = batch_axes + fft_axes
    if len(set(used)) != len(used) or not fft_axes or any(
            a >= ndim or a < 0 for a in used):
        return batch_axes, fft_axes, 1, 1, [error(
            "FFTB113",
            f"batch_axes {batch_axes} / fft_axes {fft_axes} must be "
            f"disjoint valid axes of the {ndim}-axis grid {shape} with "
            "at least one fft axis",
            location=where,
            hint="leave batch_axes/fft_axes unset for the "
                 "(batch, fft, ...) default split",
        )]
    bp = math.prod(shape[a] for a in batch_axes) if batch_axes else 1
    fp = math.prod(shape[a] for a in fft_axes)
    return batch_axes, fft_axes, bp, fp, []


# ------------------------------------------------------------- transform
def preflight_transform(spec: str, *, domains=None, grid=None, sizes=None,
                        out_domains=None) -> list[Diagnostic]:
    """Static checks for one arrow spec against concrete domains/grid."""
    from ..core.domain import Domain, SphereDomain
    from ..core.dtensor import parse_transform_spec

    diags: list[Diagnostic] = []
    try:
        (in_names, in_dist), (out_names, out_dist) = \
            parse_transform_spec(spec)
    except ValueError as err:
        return [error("FFTB101", str(err), location=repr(spec),
                      hint="spec is 'in dims -> out dims', dims "
                           "space-separated, '{i}' tags grid axes, "
                           "rename a dim (x -> X) to transform it")]

    shape = tuple(grid.shape) if grid is not None else None
    if shape is not None:
        for side, dist in (("input", in_dist), ("output", out_dist)):
            for dim, axes in sorted(dist.items()):
                for a in axes:
                    if a >= len(shape):
                        diags.append(error(
                            "FFTB102",
                            f"{side} dim {dim!r} references grid axis "
                            f"{a} but the grid has {len(shape)} axes",
                            location=repr(spec),
                            hint="match the '{i}' tags to the grid's "
                                 "axis count"))

    if domains is None:
        return diags
    if isinstance(domains, Domain):
        domains = (domains,)
    domains = tuple(domains)
    rank = sum(d.ndim for d in domains)
    if rank != len(in_names):
        diags.append(error(
            "FFTB103",
            f"spec {spec!r} has rank {len(in_names)} but the domains "
            f"have rank {rank}",
            hint="one spec dim per domain axis, domains composed in "
                 "order"))
        return diags

    # dim -> (extent, is-sphere-bbox) on the input side
    in_ext: dict[str, tuple[int, bool]] = {}
    cursor = 0
    for dom in domains:
        sphere = isinstance(dom, SphereDomain)
        for name, e in zip(in_names[cursor:cursor + dom.ndim],
                           dom.extents):
            in_ext[name] = (int(e), sphere)
        cursor += dom.ndim

    pairs = [(i, o) for i, o in zip(in_names, out_names) if i != o]
    size_map: dict[str, int] = {}
    if sizes is not None:
        if isinstance(sizes, dict):
            size_map = {k: int(v) for k, v in sizes.items()}
        else:
            sizes = tuple(sizes)
            if len(sizes) != len(pairs):
                diags.append(error(
                    "FFTB103",
                    f"{len(sizes)} sizes for {len(pairs)} transformed "
                    f"dims in {spec!r}",
                    hint="pass one size per renamed dim, in spec order"))
                return diags
            size_map = {i: int(n) for (i, _), n in zip(pairs, sizes)}

    out_ext: dict[str, tuple[int, bool]] = {}
    for i, o in zip(in_names, out_names):
        e, sphere = in_ext[i]
        if i != o:
            out_ext[o] = (size_map.get(i, e), False)
        else:
            out_ext[o] = (e, sphere)
    if out_domains is not None:
        if isinstance(out_domains, Domain):
            out_domains = (out_domains,)
        ext = [e for d in out_domains for e in d.extents]
        if len(ext) == len(out_names):
            sph = [isinstance(d, SphereDomain) for d in out_domains
                   for _ in d.extents]
            out_ext = {n: (int(e), s)
                       for n, e, s in zip(out_names, ext, sph)}

    if shape is None:
        return diags
    for side, dist, ext in (("input", in_dist, in_ext),
                            ("output", out_dist, out_ext)):
        for dim, axes in sorted(dist.items()):
            if any(a >= len(shape) for a in axes):
                continue                        # already FFTB102
            div = math.prod(shape[a] for a in axes)
            e, sphere = ext[dim]
            if e % div == 0:
                continue
            if sphere:
                diags.append(error(
                    "FFTB111",
                    f"sphere bounding-box extent {e} of {side} dim "
                    f"{dim!r} must divide over the fft-axis size {div} "
                    f"(grid axes {axes} of {shape})",
                    location=repr(spec),
                    hint="choose a cutoff diameter divisible by the "
                         "fft-axis process count"))
            else:
                diags.append(error(
                    "FFTB110",
                    f"{side} dim {dim!r} extent {e} must divide over "
                    f"grid axes {axes} (size {div}) of {shape}",
                    location=repr(spec),
                    hint="pad the extent or re-shape the process grid"))
    return diags


# ----------------------------------------------------------------- basis
def _basis_plan_bytes(spheres, segments, nbands: int, n: int, d: int
                      ) -> int:
    """Static byte estimate of a basis's full plan-cache working set.

    Per-k pack tables + mask cubes, per-segment stacked pack tables and
    band tables, plus the shared rectangular DFT operand matrices — the
    same quantities the cache bills at runtime, computed from extents
    alone.
    """
    per_k = sum(s.npacked * 4 + d ** 3 for s in spheres)
    stacked = 0
    for seg in segments:
        pad = max(spheres[i].npacked for i in seg)
        lanes = len(seg) * pad
        stacked += lanes * 5                   # int32 idx + bool valid
        stacked += 3 * lanes * 4               # kinetic/mask/precond f32
    dft = 2 * (3 * n * d * 8 + n * n * 8)      # fwd+inv operand tables
    return per_k + stacked + dft


#: VMEM budget the fused sphere-pack kernels' per-plane working set must
#: fit (one x-plane's first-stage slab + the resident packed operands).
_PALLAS_VMEM_BYTES = 16 * 2 ** 20


def preflight_basis(n: int, *, diameter: int | None = None,
                    kpts=((0.0, 0.0, 0.0),), nbands: int = 4,
                    grid=None, grid_shape=None, batch_axes=None,
                    fft_axes=None, segment_padding: float | None = None,
                    cache_max_bytes: int | None = None,
                    backend: str | None = None,
                    deep: bool = False) -> list[Diagnostic]:
    """Feasibility of a ``PlaneWaveBasis`` configuration.

    Cheap arithmetic checks always run; ``deep=True`` additionally
    builds the k-point spheres host-side (still no device work) for
    segmentation, stackability (FFTB114/115) and cache-budget (FFTB130)
    analysis — the CLI/self-audit mode.  ``backend`` (the resolved
    line-DFT backend) enables the FFTB118 pallas-constraint checks: a
    "pallas" request whose line lengths exceed the dense-DFT crossover
    or whose fused-kernel working set overflows the VMEM budget is an
    error *here*, not a silent downgrade at plan-build time.
    """
    import numpy as np

    diags: list[Diagnostic] = []
    n = int(n)
    d = int(diameter) if diameter is not None else n // 2
    if not 0 < d <= n:
        diags.append(error(
            "FFTB116", f"sphere diameter {d} not in (0, {n}]",
            location="diameter",
            hint="the cutoff sphere must fit the FFT cube "
                 "(conventionally d = n/2)"))

    shape = _grid_shape(grid, grid_shape)
    if shape is None:
        shape = (1,)
    batch_axes, fft_axes, bp, fp, axis_diags = _axes_split(
        shape, batch_axes, fft_axes, where="grid")
    diags.extend(axis_diags)
    if axis_diags:
        return diags

    if int(nbands) % bp:
        diags.append(error(
            "FFTB112",
            f"nbands {int(nbands)} not divisible by the batch-axis "
            f"size {bp} of the grid {shape}",
            location="nbands",
            hint="round nbands up to a multiple of the batch-axis "
                 "process count"))
    if n % fp:
        diags.append(error(
            "FFTB110",
            f"cube width {n} must divide over the fft-axis size {fp} "
            f"of the grid {shape}",
            location="n",
            hint="choose n as a multiple of the fft-axis process "
                 "count"))
    if d > 0 and d % fp:
        diags.append(error(
            "FFTB111",
            f"sphere diameter {d} must divide over the fft-axis size "
            f"{fp} of the grid {shape}",
            location="diameter",
            hint="choose a cutoff diameter divisible by the fft-axis "
                 "process count"))

    kpts = np.atleast_2d(np.asarray(kpts, np.float64))
    if kpts.ndim != 2 or kpts.shape[1] != 3:
        diags.append(error(
            "FFTB120", f"kpts must be (nk, 3), got shape {kpts.shape}",
            location="kpts",
            hint="one reduced-coordinate 3-vector per k-point"))
        return diags
    nk = kpts.shape[0]

    if segment_padding is not None and not 0.0 <= segment_padding < 1.0:
        diags.append(error(
            "FFTB117",
            f"segment_padding must be in [0, 1), got {segment_padding}",
            location="segment_padding",
            hint="it is a padded-lane *fraction* budget"))

    if backend is not None:
        from ..core.local_fft import _BACKENDS, MATMUL_MAX_N
        if backend not in _BACKENDS:
            diags.append(error(
                "FFTB118",
                f"unknown line-DFT backend {backend!r}",
                location="backend",
                hint=f"choose one of {_BACKENDS}"))
        elif backend == "pallas" and d > 0:
            if max(n, d) > MATMUL_MAX_N:
                diags.append(error(
                    "FFTB118",
                    f"backend 'pallas' requested but the line lengths "
                    f"(n={n}, d={d}) exceed the dense-DFT crossover "
                    f"{MATMUL_MAX_N} — the fused sphere-pack kernels "
                    "would silently realize as 'jnp'",
                    location="backend",
                    hint="shrink the cube/cutoff below the crossover or "
                         "request backend='jnp' explicitly"))
            else:
                # fused unpack-DFT working set per grid step: one
                # x-plane's (B_loc, ey, n) re/im slab plus the resident
                # packed operands, DFT planes and line tables — all f32
                b_loc = max(nk * int(nbands) // max(bp, 1), 1)
                npk = int(math.pi / 6.0 * d ** 3) + 1
                slab = (8 * b_loc * (d * n + npk) + 8 * n * d
                        + 12 * b_loc * d)
                if slab > _PALLAS_VMEM_BYTES:
                    diags.append(error(
                        "FFTB118",
                        f"fused sphere-pack working set ~{slab} bytes "
                        f"per x-plane exceeds the {_PALLAS_VMEM_BYTES}-"
                        "byte VMEM budget",
                        location="backend",
                        hint="shrink nbands/nk or the cutoff diameter, "
                             "or use backend='matmul' (unfused)"))

    if not deep or any(dg.is_error for dg in diags):
        return diags

    # ---- deep mode: build spheres host-side, no device work ----------
    from ..core.planewave import kpoint_sphere, segment_spheres

    spheres = [kpoint_sphere(d, kp) for kp in kpts]
    if segment_padding is None:
        segments = (tuple(range(nk)),)
    else:
        div = bp if bp > 1 else None
        segments = segment_spheres(spheres, segment_padding,
                                   size_divisor=div)

    if bp > 1:
        bad = [seg for seg in segments
               if bp % len(seg) or (len(seg) * int(nbands)) % bp]
        if bad and segment_padding is not None:
            diags.append(error(
                "FFTB115",
                f"segment sizes {[len(s) for s in bad]} violate the "
                f"batch-axis size_divisor contract (batch procs {bp}, "
                f"nbands {int(nbands)})",
                location="segment_padding",
                hint="segment lengths must divide the batch-axis size "
                     "and nk_seg*nbands must be divisible by it"))
        elif bad and nk > 1:
            diags.append(warning(
                "FFTB114",
                f"nk={nk} does not stack over the batch-axis size "
                f"{bp} (nbands {int(nbands)}) — the stacked route "
                "falls back to per-k dispatch",
                location="kpts",
                hint="set segment_padding to let the segmenter emit "
                     "divisor-sized segments, or choose nk so "
                     "nk*nbands splits over the batch axes"))

    est = _basis_plan_bytes(spheres, segments, int(nbands), n, d)
    if cache_max_bytes is None:
        from ..core.cache import global_plan_cache
        cache_max_bytes = global_plan_cache().max_bytes
    if est > int(cache_max_bytes):
        diags.append(error(
            "FFTB130",
            f"estimated plan working set ~{est} bytes exceeds the "
            f"plan-cache byte budget {int(cache_max_bytes)} — every "
            "SCF iteration would rebuild evicted plans",
            location="cache.max_bytes",
            hint="raise PlanCache(max_bytes=...) or shrink "
                 "nk/diameter"))
    return diags


# --------------------------------------------------------------- service
def preflight_service(n: int, *, grid=None, grid_shape=None,
                      batch_axes=(), fft_axes=None, max_rows: int = 8,
                      padding_budget: float = 0.5,
                      diameters=()) -> list[Diagnostic]:
    """Feasibility of a ``TransformService`` configuration."""
    diags: list[Diagnostic] = []
    n = int(n)
    shape = _grid_shape(grid, grid_shape)
    if shape is None:
        shape = (1,)
    batch_axes, fft_axes, _, fp, axis_diags = _axes_split(
        shape, batch_axes if batch_axes is not None else (), fft_axes,
        where="grid")
    diags.extend(axis_diags)
    if axis_diags:
        return diags

    if n % fp:
        diags.append(error(
            "FFTB110",
            f"cube width {n} must divide over the fft-axis size {fp} "
            f"of the grid {shape}",
            location="n",
            hint="choose n as a multiple of the fft-axis process "
                 "count"))
    if int(max_rows) < 1:
        diags.append(error(
            "FFTB122", f"max_rows must be >= 1, got {max_rows}",
            location="max_rows",
            hint="max_rows caps the coalesced batch's row bucket"))
    if not 0.0 <= float(padding_budget) < 1.0:
        diags.append(error(
            "FFTB117",
            f"padding_budget must be in [0, 1), got {padding_budget}",
            location="padding_budget",
            hint="it is a padded-lane *fraction* budget"))
    for raw in diameters:
        d = int(raw)
        if not 0 < d <= n:
            diags.append(error(
                "FFTB116", f"sphere diameter {d} not in (0, {n}]",
                location="diameters",
                hint="request cutoffs must fit the service's cube"))
        elif d % fp:
            diags.append(error(
                "FFTB111",
                f"sphere diameter {d} must divide over the fft-axis "
                f"size {fp} of the grid {shape}",
                location="diameters",
                hint="this cutoff cannot shard on the service's grid"))
    return diags


def preflight_request(sphere, *, n: int, fft_procs: int,
                      max_rows: int | None = None,
                      nbands: int | None = None,
                      coeffs=None) -> list[Diagnostic]:
    """Feasibility of one ``TransformService.submit`` call."""
    import numpy as np

    diags: list[Diagnostic] = []
    if any(e % int(fft_procs) for e in sphere.extents):
        diags.append(error(
            "FFTB111",
            f"sphere extents {sphere.extents} must divide over the "
            f"fft-axis size {int(fft_procs)} — this cutoff cannot "
            "shard on the service's grid",
            location="sphere",
            hint="choose a cutoff diameter divisible by the fft-axis "
                 "process count"))
    if (max_rows is not None and nbands is not None
            and int(nbands) > int(max_rows)):
        diags.append(error(
            "FFTB122",
            f"request has {int(nbands)} bands > max_rows "
            f"{int(max_rows)}; split it",
            location="nbands",
            hint="submit several <= max_rows requests — the scheduler "
                 "coalesces them back"))
    if coeffs is not None:
        shp = tuple(np.shape(coeffs))
        if len(shp) != 2 or shp[1] != sphere.npacked or (
                nbands is not None and shp[0] != int(nbands)):
            diags.append(error(
                "FFTB120",
                f"coeffs shape {shp} does not match "
                f"(nbands, npacked={sphere.npacked})",
                location="coeffs",
                hint="pack coefficients in the sphere's CSR order"))
        dt = np.asarray(coeffs).dtype if not hasattr(coeffs, "dtype") \
            else coeffs.dtype
        if not np.issubdtype(dt, np.complexfloating):
            diags.append(error(
                "FFTB121",
                f"coefficients must be complex, got dtype {dt}",
                location="coeffs",
                hint="plane-wave coefficients are complex64"))
    return diags


# ------------------------------------------------------------- umbrella
def preflight_config(cfg: dict, *, name: str = "",
                     grid_shape=None) -> list[Diagnostic]:
    """Audit one scenario/config dict (``benchmarks/baseline.json``).

    ``scf``-style records route to :func:`preflight_basis` (deep),
    ``serve``-style records (``tenants``/``max_rows`` keys) to
    :func:`preflight_service`.
    """
    cfg = dict(cfg)
    shape = grid_shape or cfg.get("grid_shape")
    if shape is None and cfg.get("devices"):
        shape = (int(cfg["devices"]),)
    loc = name or "config"
    if "tenants" in cfg or cfg.get("kind") == "service":
        diams = [cfg[k] for k in ("d", "d_small") if cfg.get(k)]
        diags = preflight_service(
            cfg["n"], grid_shape=shape,
            batch_axes=tuple(cfg.get("batch_axes", ())),
            fft_axes=cfg.get("fft_axes"),
            max_rows=cfg.get("max_rows", 8),
            padding_budget=cfg.get("padding_budget", 0.5),
            diameters=diams)
    else:
        diags = preflight_basis(
            cfg["n"], diameter=cfg.get("diameter"),
            kpts=cfg.get("kpts", ((0.0, 0.0, 0.0),)),
            nbands=cfg.get("nbands", 4), grid_shape=shape,
            batch_axes=cfg.get("batch_axes"),
            fft_axes=cfg.get("fft_axes"),
            segment_padding=cfg.get("segment_padding"),
            cache_max_bytes=cfg.get("cache_max_bytes"),
            backend=cfg.get("backend"), deep=True)
    return [Diagnostic(dg.code, dg.severity, dg.message,
                       f"{loc}: {dg.location}" if dg.location else loc,
                       dg.hint) for dg in diags]


def preflight_scenario(name: str, record: dict) -> list[Diagnostic]:
    """Audit one full baseline.json record (scenario + grid_shape)."""
    return preflight_config(record.get("scenario", record), name=name,
                            grid_shape=record.get("grid_shape"))


def preflight(target, **kwargs) -> list[Diagnostic]:
    """Umbrella entry point, exposed as ``fftb.preflight``.

    * ``preflight("b x{0} ... -> ...", domains=, grid=, sizes=)`` —
      transform-spec checks (:func:`preflight_transform`);
    * ``preflight({"n": 16, "kpts": ..., ...})`` — config/scenario
      checks (:func:`preflight_config`).

    Returns the diagnostics list (possibly empty); never raises.
    """
    if isinstance(target, str):
        return preflight_transform(target, **kwargs)
    if isinstance(target, dict):
        return preflight_config(target, **kwargs)
    raise TypeError(
        f"preflight expects an arrow-spec string or a config dict, "
        f"got {type(target).__name__}")


def check_transform(spec: str, *, domains=None, grid=None, sizes=None,
                    out_domains=None) -> None:
    """Raise :class:`DiagnosticError` on any transform preflight error.

    The ``fftb.plan_for`` hook — runs on cache misses only.
    """
    raise_if_errors(preflight_transform(
        spec, domains=domains, grid=grid, sizes=sizes,
        out_domains=out_domains))
