"""Fault-tolerant checkpointing: async, atomic, sharded, elastic.

Layout (one directory per step)::

    <root>/step_000100.tmp/...      while writing
    <root>/step_000100/
        manifest.json               logical shapes/dtypes/specs, committed last
        arr_<idx>.npy               one file per leaf (full logical array¹)

Atomicity: everything is written into a ``.tmp`` dir, fsync'd, then renamed —
a crash can never leave a half-checkpoint that restore would accept, and
``latest_step`` only reports dirs with a committed manifest.

Elasticity: the manifest stores *logical* (global) shapes + PartitionSpecs.
``restore`` rebuilds arrays with ``jax.make_array_from_callback`` against
*any* target mesh — each device reads just its slice from the npy via
np.load(mmap_mode="r"), so restoring 512-way sharded state on a different
topology (or host count) never materializes the full tensor per host.

¹ single-host container: each host writes the leaves it owns fully; on a
  real multi-host pod each host writes only its addressable shard slices —
  the manifest format (offset+extent per file) already supports that.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _spec_to_json(spec: P) -> list:
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append(e)
    return out


def _spec_from_json(lst) -> P:
    return P(*[tuple(e) if isinstance(e, list) else e for e in lst])


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        os.makedirs(root, exist_ok=True)
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, specs=None, block: bool = False):
        """Snapshot ``tree`` (device_get) and write in the background."""
        self.wait()
        leaves, treedef = _flatten(tree)
        if specs is None:
            spec_leaves = [P(*((None,) * x.ndim)) for x in leaves]
        else:
            spec_leaves = treedef.flatten_up_to(specs)
            spec_leaves = [s if isinstance(s, P) else s.spec
                           for s in spec_leaves]
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        meta = {
            "step": step,
            "time": time.time(),
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
            "leaves": [
                {"file": f"arr_{i}.npy", "shape": list(x.shape),
                 "dtype": str(x.dtype), "spec": _spec_to_json(s)}
                for i, (x, s) in enumerate(zip(host, spec_leaves))],
        }

        def write():
            tmp = os.path.join(self.root, f"step_{step:08d}.tmp")
            final = os.path.join(self.root, f"step_{step:08d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for i, x in enumerate(host):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), x)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)        # atomic commit
            self._gc()

        if self.async_write and not block:
            t = threading.Thread(target=write, daemon=True)
            t.start()
            self._pending = t
        else:
            write()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.root, d,
                                                "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, mesh=None, specs_tree=None):
        """Restore to ``mesh`` (elastic: any mesh whose axes fit).

        Returns (step, tree).  With mesh=None returns host numpy arrays.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        treedef = jax.tree_util.PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry, bytes.fromhex(meta["treedef"]))
        leaves = []
        spec_override = None
        if specs_tree is not None:
            spec_override = treedef.flatten_up_to(specs_tree)
        for i, lm in enumerate(meta["leaves"]):
            path = os.path.join(d, lm["file"])
            if mesh is None:
                leaves.append(np.load(path))
                continue
            spec = _spec_from_json(lm["spec"]) if spec_override is None \
                else spec_override[i]
            if not isinstance(spec, P):
                spec = spec.spec
            # drop axes the target mesh doesn't have (elastic down-scale)
            spec = P(*[
                (tuple(a for a in (e if isinstance(e, tuple) else (e,))
                       if a in mesh.axis_names) or None)
                if e is not None else None
                for e in spec])
            spec = P(*[e[0] if isinstance(e, tuple) and len(e) == 1 else e
                       for e in spec])
            sharding = NamedSharding(mesh, spec)
            arr = np.load(path, mmap_mode="r")
            dtype = lm["dtype"]

            def cb(idx, _arr=arr, _dt=dtype):
                return np.asarray(_arr[idx]).astype(_dt)

            leaves.append(jax.make_array_from_callback(
                tuple(lm["shape"]), sharding, cb))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
