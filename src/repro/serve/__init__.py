"""Serving: the LM decode engine and the multi-tenant transform service."""

from .engine import Request, ServeEngine
from .metrics import ServiceMetrics
from .scheduler import (CoalescingScheduler, DeadlineExceeded, QueueFull,
                        ServeError, ServiceStopped, TransformHandle,
                        TransformRequest, compat_key)
from .transform_service import TransformService

__all__ = [
    "Request", "ServeEngine",
    "TransformService", "TransformRequest", "TransformHandle",
    "CoalescingScheduler", "ServiceMetrics", "compat_key",
    "ServeError", "DeadlineExceeded", "QueueFull", "ServiceStopped",
]
