"""Observability for the transform service.

Everything the serving story needs to be judged by: per-tenant latency
percentiles (p50/p99 over submit→resolve wall time), sustained request and
transform throughput, the *realized* padding fraction of coalesced
dispatches (the quantity the scheduler's budget bounds), and the shared
``PlanCache``'s hit rate / resident bytes over the measurement window.
``summary()`` emits the dict the ``serve-transform`` bench scenario embeds
in the schema-3 gate record; ``reset()`` restarts the window (benchmarks
warm plans first, then measure a clean window).

Thread-safe: dispatch loop and tenant threads record concurrently.
"""
from __future__ import annotations

import threading
import time

import numpy as np


def _percentile_ms(samples, q: float) -> float:
    if not samples:
        return 0.0
    return float(np.percentile(np.asarray(samples, np.float64), q) * 1e3)


class ServiceMetrics:
    """Rolling counters + latency reservoirs for one service instance."""

    def __init__(self, cache=None):
        self._cache = cache
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        """Restart the measurement window (counters, reservoirs, cache

        deltas and the wall clock all restart; plans already cached keep
        their warmth — that is the point of resetting after warmup)."""
        with self._lock:
            self._t0 = time.perf_counter()
            self._lat: dict[str, list] = {}
            self._errors: dict[str, int] = {}
            self.requests = 0
            self.transforms = 0
            self.dispatches = 0
            self.coalesced_dispatches = 0
            self.rows = 0
            self._padding: list[float] = []
            if self._cache is not None:
                s = self._cache.stats
                self._cache0 = (s["hits"], s["misses"])
            else:
                self._cache0 = (0, 0)

    # ------------------------------------------------------------ recording
    def record_request(self, tenant: str, latency_s: float,
                       nbands: int) -> None:
        with self._lock:
            self._lat.setdefault(tenant, []).append(float(latency_s))
            self.requests += 1
            self.transforms += int(nbands)

    def record_error(self, kind: str) -> None:
        with self._lock:
            self._errors[kind] = self._errors.get(kind, 0) + 1

    def record_dispatch(self, nreqs: int, rows: int,
                        padding_fraction: float) -> None:
        with self._lock:
            self.dispatches += 1
            self.rows += int(rows)
            if nreqs > 1:
                self.coalesced_dispatches += 1
            self._padding.append(float(padding_fraction))

    # ------------------------------------------------------------- queries
    @property
    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    @property
    def max_padding_fraction(self) -> float:
        """Worst realized dispatch padding — the number the budget bounds."""
        with self._lock:
            return max(self._padding) if self._padding else 0.0

    def summary(self) -> dict:
        """The serving record: per-tenant percentiles + service rates.

        All latencies in milliseconds, rates over the window since the
        last ``reset()``.  Shape is stable — the bench gate reads
        ``requests_per_s`` and ``latency_p99_ms`` from the top level.
        """
        with self._lock:
            elapsed = max(time.perf_counter() - self._t0, 1e-9)
            all_lat = [v for lats in self._lat.values() for v in lats]
            per_tenant = {
                t: {"requests": len(lats),
                    "latency_p50_ms": round(_percentile_ms(lats, 50), 3),
                    "latency_p99_ms": round(_percentile_ms(lats, 99), 3)}
                for t, lats in sorted(self._lat.items())
            }
            pad = self._padding
            out = {
                "requests": self.requests,
                "requests_per_s": round(self.requests / elapsed, 2),
                "transforms": self.transforms,
                "transforms_per_s": round(self.transforms / elapsed, 2),
                "latency_p50_ms": round(_percentile_ms(all_lat, 50), 3),
                "latency_p99_ms": round(_percentile_ms(all_lat, 99), 3),
                "dispatches": self.dispatches,
                "coalesced_dispatches": self.coalesced_dispatches,
                "rows": self.rows,
                "padding_fraction_mean": round(
                    float(np.mean(pad)) if pad else 0.0, 4),
                "padding_fraction_max": round(
                    max(pad) if pad else 0.0, 4),
                "errors": dict(self._errors),
                "per_tenant": per_tenant,
            }
            if self._cache is not None:
                s = self._cache.stats
                h = s["hits"] - self._cache0[0]
                m = s["misses"] - self._cache0[1]
                out["plan_cache"] = {
                    "hits": h, "misses": m,
                    "hit_rate": round(h / max(h + m, 1), 4),
                    "resident_bytes": s["resident_bytes"],
                }
            return out
