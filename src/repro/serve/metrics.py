"""Observability for the transform service.

Everything the serving story needs to be judged by: per-tenant latency
percentiles (p50/p99 over submit→resolve wall time), sustained request and
transform throughput, the *realized* padding fraction of coalesced
dispatches (the quantity the scheduler's budget bounds), and the shared
``PlanCache``'s hit rate / resident bytes over the measurement window.
``summary()`` emits the dict the ``serve-transform`` bench scenario embeds
in the schema-4 gate record; ``reset()`` restarts the window (benchmarks
warm plans first, then measure a clean window).

Sample storage is **bounded**: latencies, queue waits and padding
fractions live in fixed-size :class:`~repro.obs.metrics.Reservoir` ring
buffers (``max_samples`` per series), so a long-lived service never grows
its metrics without bound.  Percentiles are computed over the retained
window; counts (``requests``, per-tenant ``requests``) and
``padding_fraction_max`` are all-time within the window — a running max
survives ring-buffer wraparound.  Percentile math is safe on empty and
single-sample windows (0.0 / the sample).

Thread-safe: dispatch loop and tenant threads record concurrently.
"""
from __future__ import annotations

import time

from repro.check.locks import TrackedLock
from repro.obs.metrics import Reservoir, percentile


def _percentile_ms(samples, q: float) -> float:
    """q-th percentile of ``samples`` (seconds) in milliseconds.

    Empty → 0.0, single sample → that sample; linear interpolation
    otherwise (matches ``numpy.percentile``'s default).
    """
    return percentile(samples, q) * 1e3


class ServiceMetrics:
    """Rolling counters + bounded latency reservoirs for one service.

    ``max_samples`` caps the retained samples *per series* (per-tenant
    latency, queue wait, padding); beyond it the oldest samples fall off
    while all-time counts keep counting.
    """

    def __init__(self, cache=None, *, max_samples: int = 2048):
        if max_samples < 1:
            raise ValueError("max_samples must be >= 1")
        self._cache = cache
        self.max_samples = int(max_samples)
        self._lock = TrackedLock("serve.metrics")
        self.reset()

    def reset(self) -> None:
        """Restart the measurement window (counters, reservoirs, cache

        deltas and the wall clock all restart; plans already cached keep
        their warmth — that is the point of resetting after warmup)."""
        with self._lock:
            self._t0 = time.perf_counter()
            self._lat: dict[str, Reservoir] = {}
            self._queue_wait = Reservoir(self.max_samples)
            self._errors: dict[str, int] = {}
            self.requests = 0
            self.transforms = 0
            self.dispatches = 0
            self.coalesced_dispatches = 0
            self.rows = 0
            self._padding = Reservoir(self.max_samples)
            self._padding_max = 0.0
            if self._cache is not None:
                s = self._cache.stats
                self._cache0 = (s["hits"], s["misses"])
            else:
                self._cache0 = (0, 0)

    # ------------------------------------------------------------ recording
    def record_request(self, tenant: str, latency_s: float,
                       nbands: int, queue_wait_s: float | None = None
                       ) -> None:
        with self._lock:
            res = self._lat.get(tenant)
            if res is None:
                res = self._lat[tenant] = Reservoir(self.max_samples)
            res.record(float(latency_s))
            if queue_wait_s is not None:
                self._queue_wait.record(float(queue_wait_s))
            self.requests += 1
            self.transforms += int(nbands)

    def record_error(self, kind: str) -> None:
        with self._lock:
            self._errors[kind] = self._errors.get(kind, 0) + 1

    def record_dispatch(self, nreqs: int, rows: int,
                        padding_fraction: float) -> None:
        with self._lock:
            self.dispatches += 1
            self.rows += int(rows)
            if nreqs > 1:
                self.coalesced_dispatches += 1
            self._padding.record(float(padding_fraction))
            if padding_fraction > self._padding_max:
                self._padding_max = float(padding_fraction)

    # ------------------------------------------------------------- queries
    @property
    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    @property
    def max_padding_fraction(self) -> float:
        """Worst realized dispatch padding — the number the budget bounds.

        All-time within the window: a running max, not a reservoir scan,
        so ring-buffer wraparound cannot forget the worst dispatch.
        """
        with self._lock:
            return self._padding_max

    def summary(self) -> dict:
        """The serving record: per-tenant percentiles + service rates.

        All latencies in milliseconds, rates over the window since the
        last ``reset()``.  Shape is stable — the bench gate reads
        ``requests_per_s`` and ``latency_p99_ms`` from the top level.
        Per-tenant ``requests`` counts all-time within the window;
        percentiles cover the retained samples.
        """
        with self._lock:
            elapsed = max(time.perf_counter() - self._t0, 1e-9)
            all_lat = [v for res in self._lat.values()
                       for v in res.values()]
            per_tenant = {
                t: {"requests": res.count,
                    "latency_p50_ms": round(
                        _percentile_ms(res.values(), 50), 3),
                    "latency_p99_ms": round(
                        _percentile_ms(res.values(), 99), 3)}
                for t, res in sorted(self._lat.items())
            }
            pad = self._padding.values()
            out = {
                "requests": self.requests,
                "requests_per_s": round(self.requests / elapsed, 2),
                "transforms": self.transforms,
                "transforms_per_s": round(self.transforms / elapsed, 2),
                "latency_p50_ms": round(_percentile_ms(all_lat, 50), 3),
                "latency_p99_ms": round(_percentile_ms(all_lat, 99), 3),
                "dispatches": self.dispatches,
                "coalesced_dispatches": self.coalesced_dispatches,
                "rows": self.rows,
                "padding_fraction_mean": round(
                    sum(pad) / len(pad) if pad else 0.0, 4),
                "padding_fraction_max": round(self._padding_max, 4),
                "errors": dict(self._errors),
                "per_tenant": per_tenant,
            }
            if len(self._queue_wait):
                out["queue_wait_p50_ms"] = round(
                    _percentile_ms(self._queue_wait.values(), 50), 3)
                out["queue_wait_p99_ms"] = round(
                    _percentile_ms(self._queue_wait.values(), 99), 3)
            if self._cache is not None:
                s = self._cache.stats
                h = s["hits"] - self._cache0[0]
                m = s["misses"] - self._cache0[1]
                out["plan_cache"] = {
                    "hits": h, "misses": m,
                    "hit_rate": round(h / max(h + m, 1), 4),
                    "resident_bytes": s["resident_bytes"],
                }
            return out
