"""Multi-tenant transform service over the shared PlanCache.

The long-lived serving counterpart of the dft SCF loop: many tenants
submit heterogeneous sphere-batch requests — per-request cutoff diameter,
k-shift (both folded into the request's ``SphereDomain``), band count and
optional local potential — and a continuous-batching loop coalesces
compatible requests into single ragged stacked dispatches.

Each request computes the potential-apply round trip

    out = pack( F( v_eff · F⁻¹( unpack(coeffs) ) ) )

(identity round trip when ``v_eff`` is None) — the local part of one
Hamiltonian application, i.e. the transform pair every SCF-style workload
spends its time in.

**Coalescing** rides PR 4's machinery directly: requests whose spheres
share a bounding box become *rows* of one ``StackedPlaneWaveFFT`` (one
sphere row per band, ``nbands=1``), padded to the batch's ``npacked_max``
by the pack tables — so a mixed-tenant batch is exactly two distributed
transforms, like a single big one.  Row counts are **bucketed** to the
next power of two (capped at ``max_rows``, short rows filled with inert
zero-coefficient repeats of the first sphere), so the inner d³→n³
``FftPlan`` — and its traced executors — are shared across every batch
composition of a bucket; only the cheap pack-table wrapper is
per-composition.  Both layers live in the (by default process-global)
``PlanCache``: the wrapper entries churn through byte-weighted eviction,
the inner plans are the hot shared state, and concurrent tenants exercise
the cache's build-race semantics for real.

**Admission control** keeps cold builds off the latency path: a batch
whose ``(compat, bucket)`` plans are not yet warm is requeued at the
queue fronts while a background thread builds the pair and traces its
executors on a zero round trip; the batch dispatches on a later step,
warm.  (``warm_async=False`` builds inline instead — first dispatch
pays.)

Robustness is the scheduler's: round-robin tenant fairness, queue-depth
backpressure (``QueueFull``), per-request deadlines resolved as
``DeadlineExceeded`` errors.  ``ServiceMetrics`` records what happened.
"""
from __future__ import annotations

import threading
import time

import numpy as np

import jax.numpy as jnp

from repro.check.diagnostics import raise_if_errors
from repro.check.locks import TrackedLock, check_dispatch_hazard
from repro.check.preflight import preflight_request, preflight_service
from repro.core import Domain, fftb, global_plan_cache, \
    make_stacked_planewave_pair, planewave_spec
from repro.core.cache import domains_key, grid_key
from repro.core.domain import SphereDomain
from repro.core.policy import ExecPolicy
from repro.obs.metrics import global_metrics, register_weak_probe
from repro.obs.trace import get_tracer

from .metrics import ServiceMetrics
from .scheduler import (CoalescingScheduler, DeadlineExceeded, QueueFull,
                        ServeError, ServiceStopped, TransformHandle,
                        TransformRequest, compat_key)

__all__ = ["TransformService", "TransformRequest", "TransformHandle",
           "DeadlineExceeded", "QueueFull", "ServiceStopped", "ServeError"]


def _next_pow2(x: int) -> int:
    return 1 << (int(x) - 1).bit_length()


class TransformService:
    """Continuous-batching sphere-transform server on one process grid.

    One service instance serves one ``ProcGrid`` and one FFT cube width
    ``n``; requests vary freely in sphere (cutoff/k-shift), band count,
    potential and deadline.  Drive it synchronously (``submit`` +
    ``run_until_idle``) or as a background loop (``start``/``stop``).
    """

    def __init__(self, grid, n: int, *, padding_budget: float = 0.5,
                 max_rows: int = 8, max_queue_per_tenant: int = 64,
                 backend: str = "matmul",
                 batch_axes: tuple[int, ...] = (),
                 fft_axes: tuple[int, ...] | None = None,
                 policy: ExecPolicy | None = None, cache=None,
                 coalesce: bool = True, warm_async: bool = True):
        self.grid = grid
        self.n = int(n)
        self.backend = backend
        self.batch_axes = tuple(batch_axes)
        if fft_axes is None:
            fft_axes = tuple(a for a in range(grid.ndim)
                             if a not in self.batch_axes)
        self.fft_axes = tuple(fft_axes)
        self.policy = policy
        self.fft_procs = 1
        for a in self.fft_axes:
            self.fft_procs *= grid.axis_size(a)
        # coded preflight diagnostics (FFTB110/113/117/122) replace the
        # former ad-hoc ValueError; DiagnosticError is a ValueError, so
        # existing handlers keep working
        raise_if_errors(preflight_service(
            self.n, grid=grid, batch_axes=self.batch_axes,
            fft_axes=self.fft_axes, max_rows=max_rows,
            padding_budget=padding_budget))
        self.coalesce = bool(coalesce)
        self.warm_async = bool(warm_async)
        self.max_rows = int(max_rows)
        self.cache = cache if cache is not None else global_plan_cache()
        self._pw_spec = planewave_spec(self.batch_axes, self.fft_axes)
        self.scheduler = CoalescingScheduler(
            padding_budget=padding_budget,
            max_rows=max_rows if self.coalesce else 1,
            max_queue_per_tenant=max_queue_per_tenant)
        self.metrics = ServiceMetrics(self.cache)
        # bench snapshots read the live summary through a weak probe —
        # the registry never keeps a dead service alive
        register_weak_probe(global_metrics(), "serve", self.metrics)
        self._warmed: set = set()
        self._inflight: set = set()
        self._warm_lock = TrackedLock("serve.warm")
        self._stopped = False
        self._thread: threading.Thread | None = None
        self._wake = threading.Event()

    # ------------------------------------------------------------- submit
    def submit(self, tenant: str, coeffs, sphere: SphereDomain, *,
               v_eff=None, deadline: float | None = None
               ) -> TransformHandle:
        """Enqueue one request; returns a handle to block on.

        ``coeffs``: ``(nbands, sphere.npacked)`` complex; ``deadline`` is
        *relative* seconds from now (``None`` = no deadline).  Raises
        :class:`QueueFull` past the tenant's depth cap and
        :class:`ServiceStopped` after :meth:`stop`.
        """
        if self._stopped:
            raise ServiceStopped("service is stopped")
        abs_deadline = (None if deadline is None
                        else time.perf_counter() + float(deadline))
        req = TransformRequest(tenant=tenant, coeffs=coeffs, sphere=sphere,
                               n=self.n, v_eff=v_eff, deadline=abs_deadline)
        # FFTB111 (unshardable extents) / FFTB122 (bands > max_rows)
        raise_if_errors(preflight_request(
            sphere, n=self.n, fft_procs=self.fft_procs,
            max_rows=self.max_rows, nbands=req.nbands))
        handle = self.scheduler.submit(req)
        self._wake.set()
        return handle

    def bucket_for(self, rows: int) -> int:
        """Bucketed row count: next power of two, capped at ``max_rows``."""
        return min(_next_pow2(max(int(rows), 1)), self.max_rows)

    # -------------------------------------------------------------- plans
    def _inner_plan(self, sphere: SphereDomain, bucket: int):
        """The shared d³→n³ inverse ``FftPlan`` of a ``(compat, bucket)``.

        Served through ``fftb.plan_for``'s own cache key — every batch
        composition of the same bucket hits this one plan (and its traced
        executors); the per-composition state is only the wrapper below.
        """
        bdom = Domain((0,), (bucket - 1,))
        bbox = Domain((0, 0, 0), tuple(e - 1 for e in sphere.extents))
        return fftb.plan_for(self._pw_spec, domains=(bdom, bbox),
                             grid=self.grid, sizes=(self.n,) * 3,
                             inverse=True, backend=self.backend,
                             policy=self.policy, cache=self.cache)

    def _pair_for(self, spheres: tuple, bucket: int):
        """(inverse, forward) stacked pair for one row composition.

        One sphere per row, ``nbands=1``.  The wrapper (pack tables) is
        cached per composition; the inner plan is shared per bucket.
        """
        key = ("serve-stacked", self._pw_spec, domains_key(spheres),
               bucket, grid_key(self.grid), (self.n,) * 3, self.backend,
               self.policy)
        inv = self.cache.get_or_build(
            key, lambda: make_stacked_planewave_pair(
                self.grid, self.n, list(spheres), 1, backend=self.backend,
                batch_axes=self.batch_axes, fft_axes=self.fft_axes,
                policy=self.policy,
                plan=self._inner_plan(spheres[0], bucket))[0])
        return inv, inv.inverse()

    # ---------------------------------------------------- admission control
    def _ensure_warm(self, batch) -> bool:
        """True when the batch's plans are warm enough to dispatch now.

        Cold + ``warm_async``: kick one background build per
        ``(compat, bucket)`` and report False — the caller requeues the
        batch, keeping the build off the latency path.  Cold without
        ``warm_async``: build inline and report True.
        """
        seed = batch[0].request
        rows = sum(h.request.nbands for h in batch)
        wk = (seed.compat, self.bucket_for(rows))
        if wk in self._warmed:
            return True
        if not self.warm_async:
            self._warm_build(seed.sphere, wk)
            return True
        with self._warm_lock:
            if wk in self._warmed:
                return True
            if wk not in self._inflight:
                self._inflight.add(wk)
                threading.Thread(target=self._warm_build,
                                 args=(seed.sphere, wk),
                                 daemon=True).start()
        return False

    def _warm_build(self, sphere: SphereDomain, wk) -> None:
        """Build the bucket's pair and trace its executors (zero input)."""
        _, bucket = wk
        try:
            inv, fwd = self._pair_for((sphere,) * bucket, bucket)
            z = jnp.zeros((bucket, inv.npacked_max), jnp.complex64)
            np.asarray(inv.pack(fwd(inv(inv.unpack(z)))))
        finally:
            with self._warm_lock:
                self._warmed.add(wk)
                self._inflight.discard(wk)
            self._wake.set()

    def warm(self, sphere: SphereDomain, nbands: int = 1) -> None:
        """Pre-warm the plans a ``(sphere, nbands)`` request would use."""
        wk = (compat_key(sphere, self.n), self.bucket_for(nbands))
        self._warm_build(sphere, wk)

    # ------------------------------------------------------------ dispatch
    def step(self) -> int:
        """One scheduler turn: expire deadlines, dispatch ≤ one batch.

        Returns the number of requests *resolved* this step (results or
        deadline errors); 0 means idle or stalled on a warming plan.
        """
        tr = get_tracer()
        resolved = 0
        for _h in self.scheduler.expire():
            self.metrics.record_error("deadline")
            resolved += 1
        t0 = time.perf_counter()
        batch = self.scheduler.next_batch()
        if not batch:
            return resolved
        # only non-empty batches get a coalesce event — idle polls would
        # flood the trace with zero-length noise
        tr.event("serve.coalesce", t0, time.perf_counter(),
                 requests=len(batch),
                 rows=sum(h.request.nbands for h in batch))
        if not self._ensure_warm(batch):
            self.scheduler.requeue_front(batch)
            return resolved
        try:
            self._dispatch(batch)
        except Exception as err:   # fail the batch, never hang waiters
            for h in batch:
                h._fail(ServeError(f"dispatch failed: {err!r}"))
            self.metrics.record_error("dispatch")
            raise
        return resolved + len(batch)

    def _dispatch(self, batch) -> None:
        check_dispatch_hazard("serve.dispatch")
        tr = get_tracer()
        now = time.perf_counter()
        for h in batch:
            h.dispatched_at = now
        reqs = [h.request for h in batch]
        rows = sum(r.nbands for r in reqs)
        bucket = self.bucket_for(rows)
        padding = CoalescingScheduler.batch_padding(batch)
        with tr.span("serve.dispatch", requests=len(reqs), rows=rows,
                     bucket=bucket, padding=round(padding, 4)):
            spheres: list = []
            for r in reqs:
                spheres.extend([r.sphere] * r.nbands)
            spheres.extend([spheres[0]] * (bucket - rows))  # inert rows
            inv, fwd = self._pair_for(tuple(spheres), bucket)

            buf = np.zeros((bucket, inv.npacked_max), np.complex64)
            r0 = 0
            for r in reqs:
                buf[r0:r0 + r.nbands, :r.sphere.npacked] = r.coeffs
                r0 += r.nbands
            psi = inv(inv.unpack(jnp.asarray(buf)))
            if any(r.v_eff is not None for r in reqs):
                v = np.ones((bucket,) + (self.n,) * 3, np.float32)
                r0 = 0
                for r in reqs:
                    if r.v_eff is not None:
                        v[r0:r0 + r.nbands] = r.v_eff
                    r0 += r.nbands
                psi = psi * jnp.asarray(v)
            # np.asarray materializes the result — the span end is an
            # honest completion time without an extra sync
            out = np.asarray(inv.pack(fwd(psi)))

        self.metrics.record_dispatch(len(reqs), rows, padding)
        r0 = 0
        for h, r in zip(batch, reqs):
            h._resolve(out[r0:r0 + r.nbands, :r.sphere.npacked].copy())
            r0 += r.nbands
            self.metrics.record_request(
                r.tenant, h.latency, r.nbands,
                queue_wait_s=h.queue_wait)
            tr.event("serve.request", h.submitted_at, h.completed_at,
                     tenant=r.tenant, rid=r.rid, nbands=r.nbands,
                     queue_wait_ms=round(h.queue_wait * 1e3, 3))

    # ------------------------------------------------------- eager oracle
    def eager_apply(self, coeffs, sphere: SphereDomain, v_eff=None
                    ) -> np.ndarray:
        """Per-request dispatch, no coalescing — the correctness oracle.

        Same math as one dispatched request (cached per-sphere
        ``PlaneWaveFFT`` pair, batch = the request's own bands); the
        coalesced path must match this bitwise.
        """
        coeffs = np.asarray(coeffs, np.complex64)
        bdom = Domain((0,), (coeffs.shape[0] - 1,))
        inv = fftb.plan_for(self._pw_spec, domains=(bdom, sphere),
                            grid=self.grid, sizes=(self.n,) * 3,
                            inverse=True, backend=self.backend,
                            policy=self.policy, cache=self.cache)
        fwd = inv.inverse()
        psi = inv(inv.unpack(jnp.asarray(coeffs)))
        if v_eff is not None:
            psi = psi * jnp.asarray(np.asarray(v_eff, np.float32))
        return np.asarray(inv.pack(fwd(psi)))

    # ----------------------------------------------------------- lifecycle
    def run_until_idle(self, timeout: float = 60.0) -> int:
        """Step until every queued request is resolved; returns count."""
        t0 = time.perf_counter()
        total = 0
        while len(self.scheduler):
            if time.perf_counter() - t0 > timeout:
                raise TimeoutError(
                    f"{len(self.scheduler)} requests still queued after "
                    f"{timeout}s")
            n = self.step()
            total += n
            if n == 0 and len(self.scheduler):
                # stalled on a warming plan (or racing submitters):
                # wait for a wake signal rather than spinning
                self._wake.wait(0.005)
                self._wake.clear()
        return total

    def start(self) -> None:
        """Run the dispatch loop on a background thread (until ``stop``)."""
        if self._thread is not None:
            return
        self._stopped = False

        def loop():
            while not self._stopped:
                try:
                    n = self.step()
                except Exception:      # batch already failed; keep serving
                    continue
                if n == 0:
                    self._wake.wait(0.005)
                    self._wake.clear()

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop serving; pending requests drain (default) or fail.

        With ``drain=False`` every queued request resolves immediately
        with :class:`ServiceStopped` — waiters never hang.
        """
        if drain and not self._stopped:
            self.run_until_idle(timeout=timeout)
        self._stopped = True
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        for _ in self.scheduler.fail_all(
                ServiceStopped("service stopped with requests queued")):
            self.metrics.record_error("stopped")
