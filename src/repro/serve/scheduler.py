"""Continuous-batching scheduler for the multi-tenant transform service.

Requests arrive from many tenants, each carrying its own cut-off sphere
(cutoff + k-shift), band count and optional deadline.  The scheduler's job
is the serving half of the paper's batching argument: transforms whose
spheres share one bounding box (same cutoff diameter ``d``, same FFT cube
``n``) differ only in their static pack tables, so they can ride a single
ragged stacked dispatch (``StackedPlaneWaveFFT``) — *if* the padding the
ragged batch introduces is worth it.  A configurable **padding budget**
decides: a candidate joins the batch only while

    1 − Σ_i bands_i · npacked_i / (rows · npacked_max)  ≤  budget

(rows = Σ bands_i; a batch of one request always has padding 0, so every
request is admissible alone and the budget can never deadlock).

Fairness is round-robin over tenants: each tenant holds a FIFO deque, the
batch *seed* rotates through non-empty tenants, and batch fill iterates
tenants in the same rotating order — a tenant flooding its queue cannot
starve the others.  Queue-depth backpressure (``QueueFull``) and absolute
per-request deadlines (``DeadlineExceeded``, resolved by ``expire`` as an
error on the handle, never a hang) bound the damage of overload.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque

import numpy as np

from repro.check.locks import TrackedLock
from repro.core.domain import SphereDomain


class ServeError(RuntimeError):
    """Base class of transform-service request failures."""


class DeadlineExceeded(ServeError):
    """The request's deadline passed before it was dispatched."""


class QueueFull(ServeError):
    """The tenant's queue is at ``max_queue_per_tenant`` — back off."""


class ServiceStopped(ServeError):
    """The service shut down with the request still queued."""


def compat_key(sphere: SphereDomain, n: int) -> tuple:
    """Batch-compatibility class of a request.

    Two requests can share one stacked dispatch iff their spheres share a
    bounding box (equal extents — same cutoff diameter, any k-shift or
    radius below it) and target the same FFT cube width ``n``: then the
    inner d³→n³ plan is identical and only the pack tables differ.
    """
    return (tuple(sphere.extents), int(n))


@dataclasses.dataclass
class TransformRequest:
    """One tenant's unit of work: packed coefficients through the service.

    ``coeffs`` is ``(nbands, sphere.npacked)`` complex64; ``v_eff`` an
    optional real ``(n, n, n)`` local potential applied point-wise in real
    space between the inverse and forward transforms (``None`` = pure
    round trip).  ``deadline`` is absolute ``time.perf_counter()`` seconds.
    """
    tenant: str
    coeffs: np.ndarray
    sphere: SphereDomain
    n: int
    v_eff: np.ndarray | None = None
    deadline: float | None = None
    rid: int = -1

    def __post_init__(self):
        self.coeffs = np.asarray(self.coeffs, np.complex64)
        if self.coeffs.ndim != 2:
            raise ValueError(
                f"coeffs must be (nbands, npacked), got {self.coeffs.shape}")
        if self.coeffs.shape[1] != self.sphere.npacked:
            raise ValueError(
                f"coeffs last dim {self.coeffs.shape[1]} != sphere "
                f"npacked {self.sphere.npacked}")
        if self.v_eff is not None:
            self.v_eff = np.asarray(self.v_eff)
            if self.v_eff.shape != (self.n,) * 3:
                raise ValueError(
                    f"v_eff shape {self.v_eff.shape} != {(self.n,) * 3}")

    @property
    def nbands(self) -> int:
        return int(self.coeffs.shape[0])

    @property
    def compat(self) -> tuple:
        return compat_key(self.sphere, self.n)


class TransformHandle:
    """Future-style result slot for a submitted request.

    ``result()`` blocks until the service resolves the handle, then
    returns the ``(nbands, npacked)`` output coefficients or raises the
    stored :class:`ServeError`.  Timestamps (``submitted_at`` /
    ``completed_at``, ``time.perf_counter()`` seconds) feed the latency
    metrics.
    """

    def __init__(self, request: TransformRequest):
        self.request = request
        self.submitted_at = time.perf_counter()
        self.dispatched_at: float | None = None
        self.completed_at: float | None = None
        self._event = threading.Event()
        self._result = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = 30.0):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.request.rid} unresolved after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def latency(self) -> float | None:
        """Submit→resolve wall seconds (None while pending)."""
        if self.completed_at is None:
            return None
        return self.completed_at - self.submitted_at

    @property
    def queue_wait(self) -> float | None:
        """Submit→dispatch wall seconds (None until dispatch starts)."""
        if self.dispatched_at is None:
            return None
        return self.dispatched_at - self.submitted_at

    # ------------------------------------------------- service-side setters
    def _resolve(self, value) -> None:
        self._result = value
        self.completed_at = time.perf_counter()
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self.completed_at = time.perf_counter()
        self._event.set()


class CoalescingScheduler:
    """Round-robin fair, padding-budgeted request coalescer.

    Thread-safe: tenants submit from their own threads, the service loop
    pulls batches from its own.  All queue state lives behind one lock;
    dispatch itself happens outside (the scheduler only forms batches).
    """

    def __init__(self, *, padding_budget: float = 0.5, max_rows: int = 8,
                 max_queue_per_tenant: int = 64):
        if not 0.0 <= padding_budget < 1.0:
            raise ValueError(f"padding_budget {padding_budget} not in [0, 1)")
        if max_rows < 1:
            raise ValueError("max_rows must be >= 1")
        if max_queue_per_tenant < 1:
            raise ValueError("max_queue_per_tenant must be >= 1")
        self.padding_budget = float(padding_budget)
        self.max_rows = int(max_rows)
        self.max_queue_per_tenant = int(max_queue_per_tenant)
        self._queues: dict[str, deque] = {}
        self._rr: deque = deque()            # tenant round-robin order
        self._rid = itertools.count()
        self._lock = TrackedLock("serve.scheduler")

    # ---------------------------------------------------------- submission
    def submit(self, request: TransformRequest) -> TransformHandle:
        """Enqueue; raises :class:`QueueFull` at the tenant's depth cap."""
        with self._lock:
            q = self._queues.get(request.tenant)
            if q is None:
                q = self._queues[request.tenant] = deque()
                self._rr.append(request.tenant)
            if len(q) >= self.max_queue_per_tenant:
                raise QueueFull(
                    f"tenant {request.tenant!r} queue at depth "
                    f"{len(q)} (max {self.max_queue_per_tenant})")
            request.rid = next(self._rid)
            handle = TransformHandle(request)
            q.append(handle)
            return handle

    def __len__(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def depth(self, tenant: str) -> int:
        with self._lock:
            q = self._queues.get(tenant)
            return 0 if q is None else len(q)

    # ------------------------------------------------------------ deadlines
    def expire(self, now: float | None = None) -> list[TransformHandle]:
        """Fail (and drop) every queued request whose deadline passed.

        Deadlines resolve as :class:`DeadlineExceeded` errors on the
        handle — an expired request never hangs its waiter and never
        occupies batch rows.
        """
        now = time.perf_counter() if now is None else now
        expired: list[TransformHandle] = []
        with self._lock:
            for q in self._queues.values():
                keep = deque()
                while q:
                    h = q.popleft()
                    d = h.request.deadline
                    if d is not None and now > d:
                        expired.append(h)
                    else:
                        keep.append(h)
                q.extend(keep)
        for h in expired:
            h._fail(DeadlineExceeded(
                f"request {h.request.rid} (tenant "
                f"{h.request.tenant!r}) deadline passed before dispatch"))
        return expired

    def fail_all(self, err: BaseException) -> list[TransformHandle]:
        """Drain every queue, failing all pending handles (shutdown path)."""
        with self._lock:
            drained = [h for q in self._queues.values() for h in q]
            for q in self._queues.values():
                q.clear()
        for h in drained:
            h._fail(err)
        return drained

    # ------------------------------------------------------------- batching
    @staticmethod
    def batch_padding(handles) -> float:
        """Padding fraction of a would-be batch (one sphere row per band)."""
        rows = sum(h.request.nbands for h in handles)
        npmax = max(h.request.sphere.npacked for h in handles)
        used = sum(h.request.nbands * h.request.sphere.npacked
                   for h in handles)
        return 1.0 - used / float(rows * npmax)

    def next_batch(self) -> list[TransformHandle]:
        """Pop the next coalesced batch (empty list when idle).

        The seed is the front request of the next non-empty tenant in
        round-robin order; fill then walks tenants in the same rotating
        order, admitting each tenant's front request while it (a) shares
        the seed's compatibility class, (b) fits under ``max_rows`` and
        (c) keeps the batch padding within the budget.  Only queue fronts
        are considered — per-tenant FIFO order is preserved.
        """
        with self._lock:
            order = [t for t in self._rr if self._queues[t]]
            if not order:
                return []
            # rotate the round-robin cursor past the seed tenant
            seed_tenant = order[0]
            while self._rr[0] != seed_tenant:
                self._rr.rotate(-1)
            self._rr.rotate(-1)

            batch = [self._queues[seed_tenant].popleft()]
            rows = batch[0].request.nbands
            key = batch[0].request.compat
            progress = True
            while progress and rows < self.max_rows:
                progress = False
                for t in order:
                    q = self._queues[t]
                    if not q:
                        continue
                    cand = q[0]
                    if cand.request.compat != key:
                        continue
                    if rows + cand.request.nbands > self.max_rows:
                        continue
                    if (self.batch_padding(batch + [cand])
                            > self.padding_budget):
                        continue
                    q.popleft()
                    batch.append(cand)
                    rows += cand.request.nbands
                    progress = True
            return batch

    def requeue_front(self, handles) -> None:
        """Push a formed batch back to its queue fronts (FIFO preserved).

        The admission-control stall path: a batch whose plan is still
        warming goes back exactly where it came from, so deadlines keep
        ticking and the next ``next_batch`` re-forms it cheaply.
        """
        with self._lock:
            for h in reversed(handles):
                self._queues[h.request.tenant].appendleft(h)
