"""Batched serving engine: prefill + decode with continuous batching.

The jitted hot path is `decode_step` over a fixed-capacity batch of slots;
the engine admits/evicts requests between steps (continuous batching), so a
finished sequence's slot is immediately refilled — the standard
vLLM/MaxText-serving control loop, sized here for CPU-CI but shaped for the
assigned decode_32k/long_500k cells.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, bundle, *, slots: int, capacity: int,
                 greedy: bool = True, cache_dtype=jnp.float32):
        self.bundle = bundle
        self.slots = slots
        self.capacity = capacity
        self.greedy = greedy
        self.params = None
        self.cache_dtype = cache_dtype
        self.cache = bundle.init_cache(slots, capacity, cache_dtype)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.active: dict[int, Request] = {}
        self.free = list(range(slots))
        # 1 where the slot decodes this step — the lengths increment is a
        # vector add with this mask, not a per-step Python comprehension
        self._active_mask = np.zeros((slots,), np.int32)
        self._decode = jax.jit(bundle.decode, donate_argnums=(2,))
        self.queue: deque[Request] = deque()
        self.steps = 0

    def load(self, params):
        self.params = params

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------ admit
    def _admit(self):
        while self.queue and self.free:
            req = self.queue.popleft()
            slot = self.free.pop(0)
            # per-slot prefill (batch=1 path reuses the bundle prefill);
            # same dtype as the batched cache — _splice's astype must be
            # an identity, not a silent up/down-cast
            cache1 = self.bundle.init_cache(1, self.capacity,
                                            self.cache_dtype)
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            logits, cache1 = self.bundle.prefill(self.params, batch, cache1)
            tok = int(jnp.argmax(logits[0, -1]))
            req.out.append(tok)
            # splice the slot into the batch cache
            self.cache = jax.tree.map(
                lambda big, one: _splice(big, one, slot), self.cache, cache1)
            self.lengths = self.lengths.at[slot].set(len(req.prompt))
            self.active[slot] = req
            self._active_mask[slot] = 1

    # ------------------------------------------------------------- step
    def step(self):
        self._admit()
        if not self.active:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0] = req.out[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache, self.lengths)
        nxt = jnp.argmax(logits[:, 0], axis=-1)
        self.lengths = self.lengths + jnp.asarray(self._active_mask)
        nxt = np.asarray(nxt)
        for slot, req in list(self.active.items()):
            req.out.append(int(nxt[slot]))
            if len(req.out) >= req.max_new:
                req.done = True
                del self.active[slot]
                self._active_mask[slot] = 0
                self.free.append(slot)
        self.steps += 1

    def run_until_done(self, max_steps: int = 10000):
        while (self.queue or self.active) and max_steps:
            self.step()
            max_steps -= 1


def _splice(big, one, slot):
    """Insert a batch-1 cache leaf into slot `slot` of the batched cache.

    Cache leaves carry the batch on axis 1 (layer-stacked) by convention.
    """
    return jax.lax.dynamic_update_slice_in_dim(
        big, one.astype(big.dtype), slot, axis=1)
