"""Model-facing spectral ops built on the FFTB local backends.

These are the integration points of the paper's infrastructure with the LM
architectures (DESIGN.md §5):

  * ``fft_conv``      — FFT long convolution (used by Mamba-2's depthwise
                        temporal conv when ``conv_impl="fft"``); causal,
                        linear-time in the kernel, O(S log S) overall.
  * ``fourier_mixer`` — FNet-style token mixer (beyond-paper demo layer).

Both operate on *local* (already sharded) data — inside a model partitioned
by GSPMD these run per-shard, exactly like FFTB's local-compute stages.
"""
from __future__ import annotations

import jax.numpy as jnp

from .local_fft import local_dft
from .policy import ExecPolicy


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _pre_cast(x, policy: ExecPolicy | None):
    """Apply the policy's compute dtype to a *real* input before the
    complex promotion (bf16 operands, f32 accumulation — same contract as
    the plans' lazy_bf16 executor)."""
    if policy is not None and policy.compute_dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x


def fft_conv(x, kernel, axis: int = 1, backend: str = "jnp",
             policy: ExecPolicy | None = None):
    """Causal depthwise convolution via frequency domain.

    x: (..., S, ...) real; kernel: (K, C) or (K,) with K ≤ S; convolves along
    ``axis`` (sequence).  Zero-padding to 2·next_pow2 avoids circular
    wrap-around — the same pad-to-avoid-aliasing requirement as the paper's
    n = 2d rule for plane-wave grids.
    """
    S = x.shape[axis]
    K = kernel.shape[0]
    if policy is not None and policy.check_shapes:
        if kernel.ndim not in (1, 2):
            raise ValueError(f"kernel must be (K,) or (K, C), "
                             f"got {kernel.shape}")
        if kernel.ndim == 2 and kernel.shape[1] != x.shape[-1]:
            raise ValueError(
                f"kernel channels {kernel.shape[1]} != input channels "
                f"{x.shape[-1]}")
    out_dtype = x.dtype
    x = _pre_cast(x, policy)
    kernel = _pre_cast(kernel, policy)
    L = _next_pow2(S + K - 1)
    xm = jnp.moveaxis(x, axis, -1)                       # (..., C, S)? keep
    # operate with seq last
    Xf = local_dft(xm.astype(jnp.complex64), -1, L, backend=backend)
    if kernel.ndim == 1:
        k = kernel[None, :]
    else:
        k = jnp.moveaxis(kernel, 0, -1)                  # (C, K)
    Kf = local_dft(k.astype(jnp.complex64), -1, L, backend=backend)
    Yf = Xf * Kf
    y = local_dft(Yf, -1, L, inverse=True, backend=backend)
    y = jnp.real(y[..., :S]).astype(out_dtype)
    return jnp.moveaxis(y, -1, axis)


def fourier_mixer(x, backend: str = "jnp",
                  policy: ExecPolicy | None = None):
    """FNet token mixing: Re(FFT_seq(FFT_hidden(x))). x: (B, S, D)."""
    if policy is not None and policy.check_shapes and x.ndim != 3:
        raise ValueError(f"fourier_mixer expects (B, S, D), got {x.shape}")
    out_dtype = x.dtype
    h = local_dft(_pre_cast(x, policy).astype(jnp.complex64), -1,
                  backend=backend)
    s = local_dft(h, -2, backend=backend)
    return jnp.real(s).astype(out_dtype)
