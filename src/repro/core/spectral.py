"""Model-facing spectral ops built on the FFTB local backends.

These are the integration points of the paper's infrastructure with the LM
architectures (DESIGN.md §5):

  * ``fft_conv``      — FFT long convolution (used by Mamba-2's depthwise
                        temporal conv when ``conv_impl="fft"``); causal,
                        linear-time in the kernel, O(S log S) overall.
  * ``fourier_mixer`` — FNet-style token mixer (beyond-paper demo layer).

Both operate on *local* (already sharded) data — inside a model partitioned
by GSPMD these run per-shard, exactly like FFTB's local-compute stages.
"""
from __future__ import annotations

import jax.numpy as jnp

from .local_fft import local_dft


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def fft_conv(x, kernel, axis: int = 1, backend: str = "jnp"):
    """Causal depthwise convolution via frequency domain.

    x: (..., S, ...) real; kernel: (K, C) or (K,) with K ≤ S; convolves along
    ``axis`` (sequence).  Zero-padding to 2·next_pow2 avoids circular
    wrap-around — the same pad-to-avoid-aliasing requirement as the paper's
    n = 2d rule for plane-wave grids.
    """
    S = x.shape[axis]
    K = kernel.shape[0]
    L = _next_pow2(S + K - 1)
    xm = jnp.moveaxis(x, axis, -1)                       # (..., C, S)? keep
    # operate with seq last
    Xf = local_dft(xm.astype(jnp.complex64), -1, L, backend=backend)
    if kernel.ndim == 1:
        k = kernel[None, :]
    else:
        k = jnp.moveaxis(kernel, 0, -1)                  # (C, K)
    Kf = local_dft(k.astype(jnp.complex64), -1, L, backend=backend)
    Yf = Xf * Kf
    y = local_dft(Yf, -1, L, inverse=True, backend=backend)
    y = jnp.real(y[..., :S]).astype(x.dtype)
    return jnp.moveaxis(y, -1, axis)


def fourier_mixer(x, backend: str = "jnp"):
    """FNet token mixing: Re(FFT_seq(FFT_hidden(x))). x: (B, S, D)."""
    h = local_dft(x.astype(jnp.complex64), -1, backend=backend)
    s = local_dft(h, -2, backend=backend)
    return jnp.real(s).astype(x.dtype)
