"""Plane-wave (sphere-batched) distributed FFT — the paper's §2.2/§3.3.

Wavefunction coefficients live inside a cut-off sphere of diameter d inside
an FFT grid of width n (conventionally n = 2d, Fig. 2).  Instead of padding
every sphere to the n³ cube up front (≈16× redundant data), the transform
pads **in stages**, fusing each pad with that dimension's line DFTs
(rectangular DFT matmuls — DESIGN.md §2) and scheduling the distributed
transpose while the moved dims are still small.

Stage schedule (inverse, sphere → real space; forward is the exact mirror
with truncating DFTs):

    in   (b, x{F}, y, z)  bounding cube d³, x sharded over fft axes F
    iDFT z : d→n   (local rectangular matmul — pad fused)
    a2a  over F    : gather x, split z       [moves b·d·d·n/F, the minimum]
    iDFT y : d→n
    iDFT x : d→n
    out  (b, X, Y, Z{F})  real-space cube, z sharded — paper Fig. 5 layout

All of this reuses FftPlan's machinery: the comm-cost schedule search finds
this order automatically; this class adds the sphere bookkeeping (CSR offset
arrays → static pack/unpack index tables) and the padded-cube baseline the
paper compares against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from .domain import Domain, SphereDomain
from .dtensor import DistTensor
from .plan import FftPlan


class PlaneWaveFFT:
    """Batched distributed sphere ↔ real-space transform."""

    def __init__(self, sphere: SphereDomain, n: tuple[int, ...],
                 tin: DistTensor, tout: DistTensor, *, inverse: bool,
                 backend: str = "matmul"):
        self.sphere = sphere
        self.n = tuple(n)
        self.inverse = inverse
        self.tin, self.tout = tin, tout
        self.grid = tin.grid
        # transformed dims are the trailing three (batch dims lead)
        pairs = list(zip(tin.dims[-3:], tout.dims[-3:]))
        self.plan = FftPlan(tin, tout, pairs, inverse=inverse,
                            backend=backend)
        self._pack_idx = jnp.asarray(sphere.pack_indices())
        self._mask = jnp.asarray(sphere.mask())

    # ------------------------------------------------------------- factory
    @staticmethod
    def from_tensors(sizes, tout, out_names, tin, in_names, grid, *,
                     inverse: bool, backend: str = "matmul"):
        sphere = next(d for d in (tin if inverse else tout).domains
                      if isinstance(d, SphereDomain))
        return PlaneWaveFFT(sphere, sizes, tin, tout, inverse=inverse,
                            backend=backend)

    # ------------------------------------------------------------- execute
    def __call__(self, x, *, mode: str = "eager"):
        return self.plan(x, mode=mode)

    # ------------------------------------------------- sphere pack/unpack
    def unpack(self, packed):
        """(…, npacked) CSR coefficients → (…, d, d, d) bounding cube."""
        d = self.sphere.extents
        flat = jnp.zeros(packed.shape[:-1] + (math.prod(d),), packed.dtype)
        flat = flat.at[..., self._pack_idx].set(packed)
        return flat.reshape(packed.shape[:-1] + d)

    def pack(self, cube):
        """(…, d, d, d) bounding cube → (…, npacked) CSR coefficients."""
        d = self.sphere.extents
        flat = cube.reshape(cube.shape[:-3] + (math.prod(d),))
        return flat[..., self._pack_idx]

    def mask_cube(self, cube):
        """Zero out everything outside the cut-off sphere (cube form)."""
        return cube * self._mask.astype(cube.dtype)

    # ---------------------------------------------------------- accounting
    def flop_count(self) -> int:
        return self.plan.flop_count()

    def comm_stats(self, itemsize: int = 8):
        return self.plan.comm_stats(itemsize)

    def describe(self) -> str:
        return ("PlaneWaveFFT sphere d=%d -> grid n=%d\n" %
                (self.sphere.extents[0], self.n[0])) + self.plan.describe()


def make_planewave_pair(grid, n: int, sphere: SphereDomain, nb: int, *,
                        backend: str = "matmul",
                        batch_axes: tuple[int, ...] = (),
                        fft_axes: tuple[int, ...] | None = None
                        ) -> tuple[PlaneWaveFFT, PlaneWaveFFT]:
    """(inverse, forward) plane-wave transforms sharing one data layout.

    inverse: sphere bounding-cube (b, x{F}, y, z) → real cube (b, X, Y, Z{F})
    forward: real cube (b, x{F'}, …) → sphere bounding-cube, exact adjoint
    layouts, so `forward(inverse(c))` round-trips without extra movement.
    """
    if fft_axes is None:
        fft_axes = tuple(a for a in range(grid.ndim) if a not in batch_axes)
    d = sphere.extents[0]
    bdom = Domain((0,), (nb - 1,))
    sph = sphere
    cube = Domain((0, 0, 0), (n - 1, n - 1, n - 1))

    def spec(names, dist):
        toks = []
        for nm in names:
            ax = dist.get(nm, ())
            toks.append(nm + ("{%s}" % ",".join(map(str, ax)) if ax else ""))
        return " ".join(toks)

    bspec = {"b": tuple(batch_axes)} if batch_axes else {}
    in_i = DistTensor.create((bdom, sph), spec(
        ("b", "x", "y", "z"), {**bspec, "x": tuple(fft_axes)}), grid)
    out_i = DistTensor.create((bdom, cube), spec(
        ("b", "X", "Y", "Z"), {**bspec, "Z": tuple(fft_axes)}), grid)
    inv = PlaneWaveFFT(sph, (n, n, n), in_i, out_i, inverse=True,
                       backend=backend)

    in_f = DistTensor.create((bdom, cube), spec(
        ("b", "x", "y", "z"), {**bspec, "z": tuple(fft_axes)}), grid)
    out_f = DistTensor.create((bdom, sph), spec(
        ("b", "X", "Y", "Z"), {**bspec, "X": tuple(fft_axes)}), grid)
    fwd = PlaneWaveFFT(sph, (n, n, n), in_f, out_f, inverse=False,
                       backend=backend)
    return inv, fwd
