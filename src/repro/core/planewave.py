"""Plane-wave (sphere-batched) distributed FFT — the paper's §2.2/§3.3.

Wavefunction coefficients live inside a cut-off sphere of diameter d inside
an FFT grid of width n (conventionally n = 2d, Fig. 2).  Instead of padding
every sphere to the n³ cube up front (≈16× redundant data), the transform
pads **in stages**, fusing each pad with that dimension's line DFTs
(rectangular DFT matmuls — DESIGN.md §2) and scheduling the distributed
transpose while the moved dims are still small.

Stage schedule (inverse, sphere → real space; forward is the exact mirror
with truncating DFTs):

    in   (b, x{F}, y, z)  bounding cube d³, x sharded over fft axes F
    iDFT z : d→n   (local rectangular matmul — pad fused)
    a2a  over F    : gather x, split z       [moves b·d·d·n/F, the minimum]
    iDFT y : d→n
    iDFT x : d→n
    out  (b, X, Y, Z{F})  real-space cube, z sharded — paper Fig. 5 layout

All of this reuses FftPlan's machinery: the comm-cost schedule search finds
this order automatically; this class adds the sphere bookkeeping (CSR offset
arrays → static pack/unpack index tables).  The mirror transform is *derived*
(``inverse()``/``adjoint()`` reverse the stage list), so a forward/inverse
pair costs one schedule search, not two.
"""
from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compat
from ..obs.trace import get_tracer
from .domain import Domain, SphereDomain
from .dtensor import DistTensor
from .local_fft import dft_matrix_device, realized_backend
from .plan import FFTStage, FftPlan, Plan
from .policy import ExecPolicy


# ---------------------------------------------------------- fused kernels
def _pspec_entry(grid, axes):
    """One PartitionSpec entry for a dim sharded over ``axes``."""
    if not axes:
        return None
    if len(axes) == 1:
        return grid.axis_name(axes[0])
    return tuple(grid.axis_name(a) for a in axes)


def _fused_unpack_parts(wrapper, spheres, nbands: int, npacked: int):
    """Build the fused unpack+first-stage dispatcher for ``wrapper``.

    Fusion applies when the wrapper runs the pallas backend and its plan
    opens with a local line-DFT stage on the trailing (z) dim — the staged
    schedule's d→n pad-fused stage.  That stage is replaced by the
    ``sphere_pack.unpack_dft`` kernel reading packed CSR lanes directly
    (the zero-padded bounding cube is never materialized); the remaining
    stages become a derived *remainder* plan (no second schedule search)
    whose execution keeps the dispatch-count and span accounting of the
    composed route.  Returns None when the plan shape doesn't allow it —
    callers fall back to ``unpack`` + the full plan, which is bitwise the
    same result.
    """
    from ..kernels import sphere_pack

    p = wrapper.plan
    tin, tout, grid = p.tin, p.tout, wrapper.grid
    if len(tin.dims) != 4 or not p.stages or p.scale != 1.0:
        return None
    st = p.stages[0]
    ex, ey, ez = tin.shape[1:]
    if not (isinstance(st, FFTStage) and st.index == 3 and st.n_in == ez):
        return None
    if realized_backend(st.n_in, st.n_out, wrapper.backend) != "pallas":
        return None
    bdim, xdim, ydim, zdim = tin.dims
    lay = tin.layout
    if lay.get(ydim, ()) or lay.get(zdim, ()):
        return None
    B = tin.shape[0]
    if B != len(spheres) * nbands:
        return None

    start, zlo, cnt, flag = sphere_pack.line_tables(spheres, nbands)
    wr, wi, _ = dft_matrix_device(st.n_out, st.n_in, st.inverse)
    mid = DistTensor(tin.domains[:-1]
                     + (Domain((0, 0, 0), (ex - 1, ey - 1, st.n_out - 1)),),
                     tin.dims, tin.layout, grid)
    rem = FftPlan(mid, tout,
                  [pr for pr in p.fft_pairs if pr[0] != st.dim],
                  inverse=p.is_inverse, backend=wrapper.backend,
                  policy=wrapper.policy, _stages=p.stages[1:],
                  _scale=p.scale)

    def body(packed, start, zlo, cnt, flag, wr, wi):
        packed = packed.astype(jnp.complex64)
        yr, yi = sphere_pack.unpack_dft(
            jnp.real(packed), jnp.imag(packed), start, zlo, cnt, flag,
            wr, wi)
        return jax.lax.complex(yr, yi)

    bentry = _pspec_entry(grid, lay.get(bdim, ()))
    xentry = _pspec_entry(grid, lay.get(xdim, ()))
    t_spec = P(bentry, xentry)        # line tables split with the x planes
    in_specs = (P(bentry, None), t_spec, t_spec, t_spec,
                P(xentry, None), P(None, None), P(None, None))
    fn = jax.jit(compat.shard_map(body, grid.mesh, in_specs, mid.pspec))
    tables = (jnp.asarray(start), jnp.asarray(zlo), jnp.asarray(cnt),
              jnp.asarray(flag), wr, wi)
    return {"fn": fn, "rem": rem, "tables": tables,
            "in_shape": (B, npacked), "private": tables[:4]}


def _fused_pack_parts(wrapper, spheres, nbands: int, npacked: int):
    """Build the fused last-stage+pack dispatcher for ``wrapper``.

    The mirror of :func:`_fused_unpack_parts`: when the plan *closes* with
    a local truncating line-DFT on the trailing dim, a derived *lead* plan
    runs every stage but the last, and ``sphere_pack.dft_pack`` fuses that
    final n→d stage with the CSR gather to ``(B, npacked)``.  Lane
    localization happens inside the shard_map body (each shard owns a
    contiguous x-plane range; lanes outside it are masked and merged by a
    psum over the fft axes), so padded lanes still come out exactly zero.
    """
    from ..kernels import sphere_pack

    p = wrapper.plan
    tin, tout, grid = p.tin, p.tout, wrapper.grid
    if len(tout.dims) != 4 or not p.stages or p.scale != 1.0:
        return None
    st = p.stages[-1]
    ex, ey, ez = tout.shape[1:]
    if not (isinstance(st, FFTStage) and st.index == 3 and st.n_out == ez):
        return None
    if realized_backend(st.n_in, st.n_out, wrapper.backend) != "pallas":
        return None
    bdim, xdim, ydim, zdim = tout.dims
    lay = tout.layout
    if lay.get(ydim, ()) or lay.get(zdim, ()):
        return None
    B = tout.shape[0]
    if B != len(spheres) * nbands:
        return None

    lg, zz, vv = sphere_pack.pack_gather_tables(spheres, nbands, npacked)
    wr, wi, _ = dft_matrix_device(st.n_out, st.n_in, st.inverse)
    mid = DistTensor(tout.domains[:-1]
                     + (Domain((0, 0, 0), (ex - 1, ey - 1, st.n_in - 1)),),
                     tout.dims, tout.layout, grid)
    lead = FftPlan(tin, mid,
                   [pr for pr in p.fft_pairs if pr[0] != st.dim],
                   inverse=p.is_inverse, backend=wrapper.backend,
                   policy=wrapper.policy, _stages=p.stages[:-1],
                   _scale=1.0)
    x_axes = tuple(lay.get(xdim, ()))
    names = tuple(grid.axis_name(a) for a in x_axes)
    sizes = tuple(grid.shape[a] for a in x_axes)
    d_out = st.n_out

    def body(slab, lg, zz, vv, wr, wi):
        slab = slab.astype(jnp.complex64)
        xr, xi = jnp.real(slab), jnp.imag(slab)
        ex_loc, ey_loc = xr.shape[1], xr.shape[2]
        ix = 0                       # flattened shard index over the x axes
        for nm, s in zip(names, sizes):
            ix = ix * s + jax.lax.axis_index(nm)
        ll = lg - ix * ex_loc * ey_loc          # global line → local line
        nloc = ex_loc * ey_loc
        ok = ((ll >= 0) & (ll < nloc) & (vv != 0)).astype(jnp.int32)
        g = jnp.clip(ll * d_out + zz, 0, nloc * d_out - 1).astype(jnp.int32)
        pr, pi = sphere_pack.dft_pack(xr, xi, g, ok, wr, wi)
        out = jax.lax.complex(pr, pi)
        if names:
            # each lane is gathered on exactly one shard (zeros elsewhere)
            out = jax.lax.psum(out, names)
        return out

    bentry = _pspec_entry(grid, lay.get(bdim, ()))
    in_specs = (mid.pspec, P(bentry, None), P(bentry, None),
                P(bentry, None), P(None, None), P(None, None))
    fn = jax.jit(compat.shard_map(body, grid.mesh, in_specs,
                                  P(bentry, None)))
    tables = (jnp.asarray(lg), jnp.asarray(zz), jnp.asarray(vv), wr, wi)
    return {"fn": fn, "lead": lead, "tables": tables,
            "out_shape": (B, npacked), "private": tables[:3]}


class _FusedTransformMixin:
    """Fused pack/unpack entry points shared by the plane-wave wrappers.

    ``unpack_transform``/``transform_pack`` are the hot-path API: on the
    pallas backend they route the trailing-dim line-DFT stage through the
    fused sphere-pack kernels; on every other backend (or when the plan
    shape rules fusion out) they compose the existing ``unpack``/``pack``
    with the full plan — same result, bit for bit.
    """

    def _fused_in_parts(self):
        memo = self.__dict__.get("_fused_in_memo", "unset")
        if memo == "unset":
            memo = _fused_unpack_parts(self, self._fusion_spheres,
                                       self._fusion_nbands,
                                       self._fusion_npacked)
            self.__dict__["_fused_in_memo"] = memo
        return memo

    def _fused_out_parts(self):
        memo = self.__dict__.get("_fused_out_memo", "unset")
        if memo == "unset":
            memo = _fused_pack_parts(self, self._fusion_spheres,
                                     self._fusion_nbands,
                                     self._fusion_npacked)
            self.__dict__["_fused_out_memo"] = memo
        return memo

    def unpack_transform(self, packed, *, policy: ExecPolicy | None = None):
        """``unpack`` + transform in one go — fused on the pallas backend.

        The fused route needs the eager executor and the exact ``(B,
        npacked)`` hot-path shape; anything else falls back to the composed
        route (bitwise-identical output).
        """
        pol = self.resolve_policy(policy=policy)
        parts = self._fused_in_parts()
        if (parts is None or pol.mode != "eager"
                or tuple(packed.shape) != parts["in_shape"]):
            return self(self.unpack(packed), policy=pol)
        from ..kernels import sphere_pack
        sphere_pack.DISPATCHES["unpack_dft"] += 1
        tr = get_tracer()
        if tr.enabled and not compat.is_tracer(packed):
            with tr.span("fused:unpack_dft", backend="pallas",
                         npacked=parts["in_shape"][1]) as sp:
                mid = sp.sync(parts["fn"](packed, *parts["tables"]))
        else:
            mid = parts["fn"](packed, *parts["tables"])
        return parts["rem"](mid, policy=pol)

    def transform_pack(self, cube, *, policy: ExecPolicy | None = None):
        """Transform + ``pack`` in one go — fused on the pallas backend."""
        pol = self.resolve_policy(policy=policy)
        parts = self._fused_out_parts()
        if parts is None or pol.mode != "eager":
            return self.pack(self(cube, policy=pol))
        from ..kernels import sphere_pack
        sphere_pack.DISPATCHES["dft_pack"] += 1
        mid = parts["lead"](cube, policy=pol)
        tr = get_tracer()
        if tr.enabled and not compat.is_tracer(cube):
            with tr.span("fused:dft_pack", backend="pallas",
                         npacked=parts["out_shape"][1]) as sp:
                return sp.sync(parts["fn"](mid, *parts["tables"]))
        return parts["fn"](mid, *parts["tables"])

    def _fused_table_bytes(self) -> int:
        tot = 0
        for key in ("_fused_in_memo", "_fused_out_memo"):
            parts = self.__dict__.get(key)
            if isinstance(parts, dict):
                tot += sum(int(t.nbytes) for t in parts["private"])
        return tot


class PlaneWaveFFT(_FusedTransformMixin, Plan):
    """Batched distributed sphere ↔ real-space transform."""

    def __init__(self, sphere: SphereDomain, n: tuple[int, ...],
                 tin: DistTensor, tout: DistTensor, *, inverse: bool,
                 backend: str = "matmul",
                 pairs: list[tuple[str, str]] | None = None,
                 policy: ExecPolicy | None = None,
                 plan: FftPlan | None = None):
        self.sphere = sphere
        self.n = tuple(n)
        self.is_inverse = inverse
        self.backend = backend
        self.tin, self.tout = tin, tout
        self.grid = tin.grid
        self.policy = policy if policy is not None else ExecPolicy()
        if pairs is None:
            # transformed dims default to the trailing three (batch leads)
            pairs = list(zip(tin.dims[-3:], tout.dims[-3:]))
        if plan is None:
            plan = FftPlan(tin, tout, pairs, inverse=inverse,
                           backend=backend, policy=self.policy)
        self.plan = plan
        self._pack_idx = jnp.asarray(sphere.pack_indices())
        self._mask = jnp.asarray(sphere.mask())

    # ------------------------------------------------------------- execute
    # __call__/tune come from Plan; execution delegates to the inner plan
    def _execute(self, x, pol: ExecPolicy):
        return self.plan._execute(x, pol)

    def _execute_traced(self, x, pol: ExecPolicy, tr):
        # wrap the inner plan's (possibly per-stage) spans in one
        # transform-level span tagged with the sphere shape
        with tr.span("planewave", inverse=self.is_inverse,
                     d=self.sphere.extents[0], n=self.n[0]) as sp:
            return sp.sync(self.plan._execute_traced(x, pol, tr))

    @property
    def stages(self):
        return self.plan.stages

    @property
    def dims(self):
        return self.plan.dims

    @property
    def fft_pairs(self):
        return self.plan.fft_pairs

    # ------------------------------------------------------------- mirrors
    def _mirror(self, plan: FftPlan) -> "PlaneWaveFFT":
        return PlaneWaveFFT(self.sphere, self.n, self.tout, self.tin,
                            inverse=not self.is_inverse,
                            backend=self.backend, pairs=plan.fft_pairs,
                            policy=self.policy, plan=plan)

    def _derive_inverse(self) -> "PlaneWaveFFT":
        """Derived mirror transform (no second schedule search): the
        inverse of a staged-pad plan is the staged-truncate plan."""
        return self._mirror(self.plan.inverse())

    def _derive_adjoint(self) -> "PlaneWaveFFT":
        return self._mirror(self.plan.adjoint())

    # ------------------------------------------------- sphere pack/unpack
    def unpack(self, packed):
        """(…, npacked) CSR coefficients → (…, d, d, d) bounding cube."""
        d = self.sphere.extents
        flat = jnp.zeros(packed.shape[:-1] + (math.prod(d),), packed.dtype)
        flat = flat.at[..., self._pack_idx].set(packed)
        return flat.reshape(packed.shape[:-1] + d)

    def pack(self, cube):
        """(…, d, d, d) bounding cube → (…, npacked) CSR coefficients."""
        d = self.sphere.extents
        flat = cube.reshape(cube.shape[:-3] + (math.prod(d),))
        return flat[..., self._pack_idx]

    def mask_cube(self, cube):
        """Zero out everything outside the cut-off sphere (cube form)."""
        return cube * self._mask.astype(cube.dtype)

    # ------------------------------------------------------- fused kernels
    @property
    def _fusion_spheres(self):
        return [self.sphere]

    @property
    def _fusion_nbands(self) -> int:
        # the whole batch dim rides one sphere
        return int(self.tin.shape[0])

    @property
    def _fusion_npacked(self) -> int:
        return self.sphere.npacked

    # ---------------------------------------------------------- accounting
    # flop_count/comm_stats come from Plan via the delegated stage list
    def private_bytes(self) -> int:
        """The per-sphere pack index and mask tables — what makes distinct
        spheres expensive cache entries (DFT-matrix operands are shared
        across plans and accounted via ``shared_table_bytes``)."""
        return (int(self._pack_idx.nbytes) + int(self._mask.nbytes)
                + self._fused_table_bytes() + super().private_bytes())

    def describe(self) -> str:
        return ("PlaneWaveFFT sphere d=%d -> grid n=%d\n" %
                (self.sphere.extents[0], self.n[0])) + self.plan.describe()


def kpoint_sphere(diameter: int, kpt=(0.0, 0.0, 0.0)) -> SphereDomain:
    """Cut-off sphere of a k-point: diameter ``d``, center shifted by ``k``.

    The single sphere-construction rule shared by the dft basis and the
    transform service: the Bloch factor moves the cut-off sphere's *center*
    to c0 + k (c0 the bounding-cube center, k in reduced coordinates), the
    bounding box stays the d³ cube — so every k-shift of one cutoff is
    batch-compatible (same extents, different pack tables).
    """
    d = int(diameter)
    kpt = tuple(float(k) for k in kpt)
    if len(kpt) != 3:
        raise ValueError(f"kpt must have 3 components, got {kpt}")
    c0 = (d - 1) / 2.0
    return SphereDomain(radius=d / 2.0,
                        center=tuple(c0 + k for k in kpt),
                        lower=(0, 0, 0), upper=(d - 1,) * 3)


def planewave_spec(batch_axes: tuple[int, ...] = (),
                   fft_axes: tuple[int, ...] = (0,)) -> str:
    """Arrow spec for the batched sphere↔cube transform on a given grid.

    The batch dim rides ``batch_axes`` (bands — and k-points, when the
    caller stacks them), the transform dims ride ``fft_axes``: x carries
    every fft axis on the sphere side, Z on the cube side, so the staged
    schedule's all_to_alls all run over the fft axes and the batch axes
    never communicate.  ``planewave_spec()`` with no batch axes is the 1D
    layout the dft subsystem used to pin (``"b x{0} y z -> b X Y Z{0}"``).
    """
    from .dtensor import dims_string
    bspec = {"b": tuple(batch_axes)} if batch_axes else {}
    in_s = dims_string(("b", "x", "y", "z"),
                       {**bspec, "x": tuple(fft_axes)})
    out_s = dims_string(("b", "X", "Y", "Z"),
                        {**bspec, "Z": tuple(fft_axes)})
    return f"{in_s} -> {out_s}"


def cube_spec(fft_axes: tuple[int, ...] = (0,)) -> str:
    """Arrow spec for the unbatched full-cube transform (density fields).

    Only the fft axes appear — on a (batch, fft) 2D grid the cube transform
    is replicated over the batch axes (every band/k group needs the full
    density and potential anyway).
    """
    from .dtensor import dims_string
    in_s = dims_string(("x", "y", "z"), {"z": tuple(fft_axes)})
    out_s = dims_string(("X", "Y", "Z"), {"Z": tuple(fft_axes)})
    return f"{in_s} -> {out_s}"


def make_planewave_pair(grid, n: int, sphere: SphereDomain, nb: int, *,
                        backend: str = "matmul",
                        batch_axes: tuple[int, ...] = (),
                        fft_axes: tuple[int, ...] | None = None,
                        policy: ExecPolicy | None = None
                        ) -> tuple[PlaneWaveFFT, PlaneWaveFFT]:
    """(inverse, forward) plane-wave transforms sharing one data layout.

    inverse: sphere bounding-cube (b, x{F}, y, z) → real cube (b, X, Y, Z{F})
    forward: the derived mirror (``inv.inverse()``) — exact adjoint layouts,
    so `forward(inverse(c))` round-trips without extra movement, and the
    pair costs a single schedule search.  ``batch_axes`` shard the band
    batch over extra grid axes (the paper's §3.3 batch×fft 2D grids).
    """
    if fft_axes is None:
        fft_axes = tuple(a for a in range(grid.ndim) if a not in batch_axes)
    bdom = Domain((0,), (nb - 1,))
    sph = sphere
    cube = Domain((0, 0, 0), (n - 1, n - 1, n - 1))

    in_s, out_s = planewave_spec(
        tuple(batch_axes), tuple(fft_axes)).split(" -> ")
    in_i = DistTensor.create((bdom, sph), in_s, grid)
    out_i = DistTensor.create((bdom, cube), out_s, grid)
    inv = PlaneWaveFFT(sph, (n, n, n), in_i, out_i, inverse=True,
                       backend=backend, policy=policy)
    return inv, inv.inverse()


# --------------------------------------------------------- ragged k batches
def padded_pack_tables(spheres) -> tuple[np.ndarray, np.ndarray]:
    """Index tables for a ragged batch of spheres sharing one bounding box.

    Every sphere's CSR pack order is padded to ``npacked_max = max_k
    npacked_k``.  The per-k validity mask is baked into the table itself:
    padded lanes carry the *dump-slot* index ``prod(extents)`` — one flat
    cell past the bounding cube — so an unpack scatter routes whatever sits
    in a padded lane into a slot that is dropped, and a pack gather reads
    padded lanes from a slot that is always zero.  No runtime masking, no
    extra transform math for the padding.

    Returns ``(idx, valid)``: ``idx`` is ``(nk, npacked_max)`` int32 flat
    bounding-cube indices (dump slot for padded lanes), ``valid`` the
    matching boolean lane mask.
    """
    spheres = list(spheres)
    if not spheres:
        raise ValueError("padded_pack_tables needs at least one sphere")
    ext = spheres[0].extents
    for s in spheres[1:]:
        if s.extents != ext:
            raise ValueError(
                f"ragged sphere batch must share one bounding box; got "
                f"extents {s.extents} vs {ext}")
    npmax = max(s.npacked for s in spheres)
    dump = math.prod(ext)
    idx = np.full((len(spheres), npmax), dump, np.int32)
    valid = np.zeros((len(spheres), npmax), bool)
    for k, s in enumerate(spheres):
        idx[k, :s.npacked] = s.pack_indices()
        valid[k, :s.npacked] = True
    return idx, valid


def segment_spheres(spheres, max_padding: float = 0.25,
                    size_divisor: int | None = None
                    ) -> tuple[tuple[int, ...], ...]:
    """Partition a ragged sphere batch into similar-``npacked`` segments.

    The single global ``npacked_max`` pads every k-point to the *largest*
    sphere — with strongly off-center k-shifts the padding fraction grows
    without bound.  Segmenting bounds it: spheres are ordered by
    descending ``npacked`` and greedily grouped so every segment's
    realized padding fraction ``1 − Σ npacked / (len · max npacked)``
    stays ≤ ``max_padding`` (each segment later pads only to its *own*
    maximum).  A sphere that would push the current segment over the
    budget closes it and starts the next one; singleton segments pad
    nothing, so any budget ≥ 0 is satisfiable and the bound is hard.

    ``size_divisor`` (> 1) constrains segment sizes to divisors of it —
    the batch-axis size of a stacking grid, so every segment's
    ``nk_seg · nbands`` stacked batch keeps the ``basis.stacks_k``
    sharding contract.  A closed run is then emitted as divisor-sized
    chunks, each chunk *individually* re-checked against the budget
    before it is kept (a chunk's head is its own pad target, so a
    suffix chunk pairing a big sphere with small ones can exceed the
    run's overall padding — it is split further instead; singletons pad
    nothing, so the bound stays hard).

    Returns a tuple of index tuples: a partition of ``range(len)``,
    descending ``npacked`` within and across segments.
    """
    spheres = list(spheres)
    if not spheres:
        raise ValueError("segment_spheres needs at least one sphere")
    if not 0.0 <= max_padding < 1.0:
        raise ValueError(f"max_padding must be in [0, 1), got {max_padding}")
    sizes = [s.npacked for s in spheres]
    order = sorted(range(len(spheres)), key=lambda i: (-sizes[i], i))
    tol = max_padding + 1e-12

    def pad_of(run: list[int], upto: int) -> float:
        """Padding of run[:upto] padded to its own head's npacked."""
        return 1.0 - (sum(sizes[j] for j in run[:upto])
                      / (upto * sizes[run[0]]))

    segs: list[tuple[int, ...]] = []

    def flush(run: list[int]) -> None:
        """Emit ``run`` as one segment — or, under ``size_divisor``, as
        divisor-sized chunks each re-checked against the budget."""
        while run:
            keep = len(run)
            if size_divisor and size_divisor > 1:
                keep = max(k for k in range(1, len(run) + 1)
                           if size_divisor % k == 0
                           and pad_of(run, k) <= tol)
            segs.append(tuple(run[:keep]))
            run = run[keep:]

    cur: list[int] = []
    for i in order:
        if cur and pad_of(cur + [i], len(cur) + 1) > tol:
            flush(cur)
            cur = []
        cur.append(i)
    if cur:
        flush(cur)
    return tuple(segs)


def segment_padding_fraction(spheres, segment) -> float:
    """Realized padding of one segment: 1 − Σ npacked / (len · max)."""
    sizes = [spheres[i].npacked for i in segment]
    return 1.0 - sum(sizes) / float(len(sizes) * max(sizes))


def sphere_gvectors(sphere) -> np.ndarray:
    """(npacked, 3) G+k offsets from the sphere center, in units 2π/L.

    CSR (pack) order — aligned with the packed coefficient vector.  The
    single flat-index → (x, y, z) → offset decode shared by the per-k
    ladders (``PlaneWaveBasis.gvectors``) and the padded dense tables
    below, so the two can never drift apart.
    """
    ex, ey, ez = sphere.extents
    flat = sphere.pack_indices()
    idx = np.stack([flat // (ey * ez), (flat // ez) % ey,
                    flat % ez], axis=1).astype(np.float64)
    return idx - np.asarray(sphere.center)


def sphere_kinetic_row(sphere, box_length: float) -> np.ndarray:
    """½|G+k|² over the packed coefficients (float32, CSR pack order).

    The one f64→f32 pipeline behind every kinetic ladder in the repo —
    per-k (``PlaneWaveBasis.kinetic``) and padded-dense alike — so
    "bitwise-equal on valid lanes" holds by construction, not by two
    copies staying in sync.
    """
    g = sphere_gvectors(sphere)
    g2 = (g ** 2).sum(1) * (2 * np.pi / float(box_length)) ** 2
    return 0.5 * g2.astype(np.float32)


def padded_kinetic_table(spheres, box_length: float
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Dense per-k kinetic diagonal over the padded lanes, plus the mask.

    Returns ``(kinetic, valid)``: ``kinetic`` is ``(nk, npacked_max)``
    float32 holding ½|G+k|² per packed coefficient (units set by the cell
    side ``box_length`` — a reciprocal-lattice step is 2π/L), exactly
    **zero** on padded lanes; ``valid`` is the matching boolean lane mask
    (the same one :func:`padded_pack_tables` bakes into its index table).

    This is the dense-table counterpart of the ragged per-k kinetic
    ladders: because padded lanes carry exact zeros, the table can ride
    batched einsums — Gram matrices, kinetic energies, preconditioners —
    over the full ``(nk, nbands, npacked_max)`` stack without any runtime
    masking, and padded lanes contribute exact zeros to every reduction.
    Values on valid lanes are computed with the same float64→float32
    pipeline as the per-k ladders, so the two agree bitwise.
    """
    spheres = list(spheres)
    _, valid = padded_pack_tables(spheres)      # also checks bounding boxes
    kin = np.zeros(valid.shape, np.float32)
    for k, s in enumerate(spheres):
        kin[k, :s.npacked] = sphere_kinetic_row(s, box_length)
    return kin, valid


class StackedPlaneWaveFFT(_FusedTransformMixin, Plan):
    """One sphere↔cube transform over a ragged batch of k-point spheres.

    The paper's batching argument, applied across k-points: all ``nk``
    spheres share the d³ bounding box, so their transforms differ only in
    the static pack tables — the staged-padding FFT itself can run once
    with batch ``nk·nbands`` instead of ``nk`` times with batch ``nbands``.
    Packed coefficients are padded per k to ``(nk·nbands, npacked_max)``
    with the validity masks baked into the pack/unpack tables (see
    :func:`padded_pack_tables`): padded lanes are zeros on the transform
    side and never read back, so raggedness costs only the padding
    fraction, not correctness.

    The inner ``FftPlan`` is the same d³→n³ stacked plan the density build
    uses (pass it via ``plan=`` to share the cached object and its traced
    executors); this class adds the ragged-batch bookkeeping.
    """

    def __init__(self, spheres, n: tuple[int, ...], nbands: int,
                 tin: DistTensor, tout: DistTensor, *, inverse: bool,
                 backend: str = "matmul",
                 pairs: list[tuple[str, str]] | None = None,
                 policy: ExecPolicy | None = None,
                 plan: FftPlan | None = None):
        self.spheres = list(spheres)
        self.n = tuple(n)
        self.nbands = int(nbands)
        self.is_inverse = inverse
        self.backend = backend
        self.tin, self.tout = tin, tout
        self.grid = tin.grid
        self.policy = policy if policy is not None else ExecPolicy()
        if pairs is None:
            pairs = list(zip(tin.dims[-3:], tout.dims[-3:]))
        if plan is None:
            plan = FftPlan(tin, tout, pairs, inverse=inverse,
                           backend=backend, policy=self.policy)
        self.plan = plan
        idx, valid = padded_pack_tables(self.spheres)
        self._pad_idx = jnp.asarray(idx)
        # validity is fully baked into the dump/zero slots of _pad_idx;
        # the mask is kept host-side for introspection/tests only
        self._valid = valid
        self.npacked_max = int(idx.shape[1])
        # pack-side gather table: the dump slot is clipped back into the
        # cube and masked with the lane validity instead, so ``pack`` never
        # concatenates a zero slot onto the flattened cube per dispatch
        cells = math.prod(self.extents)
        self._pack_gather_idx = jnp.asarray(np.minimum(idx, cells - 1))
        self._valid_dev = jnp.asarray(valid)

    # ------------------------------------------------------------- queries
    @property
    def nk(self) -> int:
        return len(self.spheres)

    @property
    def extents(self) -> tuple[int, ...]:
        return self.spheres[0].extents

    @property
    def padding_fraction(self) -> float:
        """Fraction of the (nk, npacked_max) lanes that are padding."""
        used = sum(s.npacked for s in self.spheres)
        return 1.0 - used / float(self.nk * self.npacked_max)

    def valid_lanes(self) -> np.ndarray:
        """(nk, npacked_max) boolean lane-validity mask (host-side copy).

        The same mask :func:`padded_pack_tables` bakes into the index
        tables — True where a lane holds a real packed coefficient,
        False on padding.
        """
        return self._valid.copy()

    # ------------------------------------------------------------- execute
    def _execute(self, x, pol: ExecPolicy):
        return self.plan._execute(x, pol)

    def _execute_traced(self, x, pol: ExecPolicy, tr):
        with tr.span("stacked_planewave", inverse=self.is_inverse,
                     nk=self.nk, npacked_max=self.npacked_max,
                     padding=round(self.padding_fraction, 4)) as sp:
            return sp.sync(self.plan._execute_traced(x, pol, tr))

    @property
    def stages(self):
        return self.plan.stages

    @property
    def dims(self):
        return self.plan.dims

    @property
    def fft_pairs(self):
        return self.plan.fft_pairs

    # ------------------------------------------------------------- mirrors
    def _mirror(self, plan: FftPlan) -> "StackedPlaneWaveFFT":
        return StackedPlaneWaveFFT(self.spheres, self.n, self.nbands,
                                   self.tout, self.tin,
                                   inverse=not self.is_inverse,
                                   backend=self.backend,
                                   pairs=plan.fft_pairs,
                                   policy=self.policy, plan=plan)

    def _derive_inverse(self) -> "StackedPlaneWaveFFT":
        return self._mirror(self.plan.inverse())

    def _derive_adjoint(self) -> "StackedPlaneWaveFFT":
        return self._mirror(self.plan.adjoint())

    # ----------------------------------------------- ragged stack helpers
    def stack(self, blocks):
        """Per-k ``(nbands, npacked_k)`` blocks → ``(nk·nbands, npacked_max)``.

        Ragged tails are zero-padded — matching the pack/unpack contract
        that padded lanes hold zeros.  One pad per block plus a single
        concatenate (linear in the total coefficient count); the padded
        blocks are pinned to one replicated placement first
        (``ProcGrid.replicate``) because eager concatenates over
        mixed-placement operands miscompute on some jax versions.
        """
        if len(blocks) != self.nk:
            raise ValueError(f"{len(blocks)} blocks for {self.nk} spheres")
        pads = [self.grid.replicate(
                    jnp.pad(c, ((0, 0), (0, self.npacked_max - c.shape[-1]))))
                for c in blocks]
        return jnp.concatenate(pads, axis=0)

    def split(self, padded):
        """``(nk·nbands, npacked_max)`` → per-k ``(nbands, npacked_k)``."""
        c = padded.reshape(self.nk, self.nbands, self.npacked_max)
        return [c[ik, :, :s.npacked] for ik, s in enumerate(self.spheres)]

    # ------------------------------------------------- sphere pack/unpack
    def unpack(self, padded):
        """``(nk·nbands, npacked_max)`` coefficients → ``(nk·nbands, d³)``.

        Each k-block scatters through its own pack table; padded lanes land
        in the dump slot and are dropped, so garbage there never reaches
        the bounding cube.
        """
        d = self.extents
        cells = math.prod(d)
        c = padded.reshape(self.nk, self.nbands, self.npacked_max)
        flat = jnp.zeros((self.nk, self.nbands, cells + 1), padded.dtype)
        kk = jnp.arange(self.nk)[:, None, None]
        bb = jnp.arange(self.nbands)[None, :, None]
        flat = flat.at[kk, bb, self._pad_idx[:, None, :]].set(c)
        return flat[..., :cells].reshape((self.nk * self.nbands,) + d)

    def pack(self, cube):
        """``(nk·nbands, d, d, d)`` cubes → ``(nk·nbands, npacked_max)``.

        Padded lanes come out exactly zero, whatever the cube holds: the
        gather table clips their dump slot back into the cube and the
        precomputed validity mask zeroes the result (``jnp.where`` yields
        +0.0, bit-identical to the old zero-slot gather) — no per-dispatch
        zero-slot concatenate on the hot path.
        """
        d = self.extents
        cells = math.prod(d)
        flat = cube.reshape(self.nk, self.nbands, cells)
        # take_along_axis keeps the gather single-indexed: no per-dispatch
        # start-index concatenate in the lowered computation
        idx = jnp.broadcast_to(self._pack_gather_idx[:, None, :],
                               (self.nk, self.nbands, self.npacked_max))
        out = jnp.take_along_axis(flat, idx, axis=2)
        out = jnp.where(self._valid_dev[:, None, :], out, 0)
        return out.reshape(self.nk * self.nbands, self.npacked_max)

    # ------------------------------------------------------- fused kernels
    @property
    def _fusion_spheres(self):
        return self.spheres

    @property
    def _fusion_nbands(self) -> int:
        return self.nbands

    @property
    def _fusion_npacked(self) -> int:
        return self.npacked_max

    # ---------------------------------------------------------- accounting
    def private_bytes(self) -> int:
        """The ragged pack tables are per-sphere-set — never shared."""
        return (int(self._pad_idx.nbytes) + int(self._valid.nbytes)
                + int(self._pack_gather_idx.nbytes)
                + int(self._valid_dev.nbytes)
                + self._fused_table_bytes() + super().private_bytes())

    def describe(self) -> str:
        return ("StackedPlaneWaveFFT %d spheres d=%d -> grid n=%d "
                "(npacked_max=%d, padding %.1f%%)\n" %
                (self.nk, self.extents[0], self.n[0], self.npacked_max,
                 100 * self.padding_fraction)) + self.plan.describe()


def make_stacked_planewave_pair(grid, n: int, spheres, nbands: int, *,
                                backend: str = "matmul",
                                batch_axes: tuple[int, ...] = (),
                                fft_axes: tuple[int, ...] | None = None,
                                policy: ExecPolicy | None = None,
                                plan: FftPlan | None = None
                                ) -> tuple["StackedPlaneWaveFFT",
                                           "StackedPlaneWaveFFT"]:
    """(inverse, forward) ragged-batch stacked pair over nk·nbands orbitals.

    Layouts match :func:`make_planewave_pair` with the batch dim widened to
    ``nk·nbands`` and the sphere side opened to the shared d³ bounding box
    (the raggedness lives in the pack tables, not the plan).  Pass ``plan=``
    to wrap an already-built (cached) d³→n³ inverse ``FftPlan`` — e.g. the
    density build's stacked plan — instead of constructing a second one.
    """
    spheres = list(spheres)
    if fft_axes is None:
        fft_axes = tuple(a for a in range(grid.ndim) if a not in batch_axes)
    nk = len(spheres)
    ext = spheres[0].extents
    if plan is not None:
        tin, tout = plan.tin, plan.tout
    else:
        bdom = Domain((0,), (nk * nbands - 1,))
        bbox = Domain((0, 0, 0), tuple(e - 1 for e in ext))
        cube = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
        in_s, out_s = planewave_spec(
            tuple(batch_axes), tuple(fft_axes)).split(" -> ")
        tin = DistTensor.create((bdom, bbox), in_s, grid)
        tout = DistTensor.create((bdom, cube), out_s, grid)
    inv = StackedPlaneWaveFFT(spheres, (n, n, n), nbands, tin, tout,
                              inverse=True, backend=backend, policy=policy,
                              plan=plan)
    return inv, inv.inverse()
