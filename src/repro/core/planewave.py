"""Plane-wave (sphere-batched) distributed FFT — the paper's §2.2/§3.3.

Wavefunction coefficients live inside a cut-off sphere of diameter d inside
an FFT grid of width n (conventionally n = 2d, Fig. 2).  Instead of padding
every sphere to the n³ cube up front (≈16× redundant data), the transform
pads **in stages**, fusing each pad with that dimension's line DFTs
(rectangular DFT matmuls — DESIGN.md §2) and scheduling the distributed
transpose while the moved dims are still small.

Stage schedule (inverse, sphere → real space; forward is the exact mirror
with truncating DFTs):

    in   (b, x{F}, y, z)  bounding cube d³, x sharded over fft axes F
    iDFT z : d→n   (local rectangular matmul — pad fused)
    a2a  over F    : gather x, split z       [moves b·d·d·n/F, the minimum]
    iDFT y : d→n
    iDFT x : d→n
    out  (b, X, Y, Z{F})  real-space cube, z sharded — paper Fig. 5 layout

All of this reuses FftPlan's machinery: the comm-cost schedule search finds
this order automatically; this class adds the sphere bookkeeping (CSR offset
arrays → static pack/unpack index tables).  The mirror transform is *derived*
(``inverse()``/``adjoint()`` reverse the stage list), so a forward/inverse
pair costs one schedule search, not two.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from .domain import Domain, SphereDomain
from .dtensor import DistTensor
from .plan import FftPlan, Plan
from .policy import ExecPolicy


class PlaneWaveFFT(Plan):
    """Batched distributed sphere ↔ real-space transform."""

    def __init__(self, sphere: SphereDomain, n: tuple[int, ...],
                 tin: DistTensor, tout: DistTensor, *, inverse: bool,
                 backend: str = "matmul",
                 pairs: list[tuple[str, str]] | None = None,
                 policy: ExecPolicy | None = None,
                 plan: FftPlan | None = None):
        self.sphere = sphere
        self.n = tuple(n)
        self.is_inverse = inverse
        self.backend = backend
        self.tin, self.tout = tin, tout
        self.grid = tin.grid
        self.policy = policy if policy is not None else ExecPolicy()
        if pairs is None:
            # transformed dims default to the trailing three (batch leads)
            pairs = list(zip(tin.dims[-3:], tout.dims[-3:]))
        if plan is None:
            plan = FftPlan(tin, tout, pairs, inverse=inverse,
                           backend=backend, policy=self.policy)
        self.plan = plan
        self._pack_idx = jnp.asarray(sphere.pack_indices())
        self._mask = jnp.asarray(sphere.mask())

    # ------------------------------------------------------------- factory
    @staticmethod
    def from_tensors(sizes, tout, out_names, tin, in_names, grid, *,
                     inverse: bool, backend: str = "matmul",
                     policy: ExecPolicy | None = None):
        side = tin if inverse else tout
        sphere = next((d for d in side.domains
                       if isinstance(d, SphereDomain)), None)
        if sphere is None:
            which = "input" if inverse else "output"
            kinds = [type(d).__name__ for d in side.domains]
            raise ValueError(
                f"PlaneWaveFFT needs a SphereDomain among the {which} "
                f"domains (the packed side of the transform); got "
                f"{kinds} for dims {side.dims}")
        pairs = list(zip(in_names, out_names))
        return PlaneWaveFFT(sphere, sizes, tin, tout, inverse=inverse,
                            backend=backend, pairs=pairs, policy=policy)

    # ------------------------------------------------------------- execute
    # __call__/tune come from Plan; execution delegates to the inner plan
    def _execute(self, x, pol: ExecPolicy):
        return self.plan._execute(x, pol)

    @property
    def stages(self):
        return self.plan.stages

    @property
    def dims(self):
        return self.plan.dims

    @property
    def fft_pairs(self):
        return self.plan.fft_pairs

    # ------------------------------------------------------------- mirrors
    def _mirror(self, plan: FftPlan) -> "PlaneWaveFFT":
        return PlaneWaveFFT(self.sphere, self.n, self.tout, self.tin,
                            inverse=not self.is_inverse,
                            backend=self.backend, pairs=plan.fft_pairs,
                            policy=self.policy, plan=plan)

    def _derive_inverse(self) -> "PlaneWaveFFT":
        """Derived mirror transform (no second schedule search): the
        inverse of a staged-pad plan is the staged-truncate plan."""
        return self._mirror(self.plan.inverse())

    def _derive_adjoint(self) -> "PlaneWaveFFT":
        return self._mirror(self.plan.adjoint())

    # ------------------------------------------------- sphere pack/unpack
    def unpack(self, packed):
        """(…, npacked) CSR coefficients → (…, d, d, d) bounding cube."""
        d = self.sphere.extents
        flat = jnp.zeros(packed.shape[:-1] + (math.prod(d),), packed.dtype)
        flat = flat.at[..., self._pack_idx].set(packed)
        return flat.reshape(packed.shape[:-1] + d)

    def pack(self, cube):
        """(…, d, d, d) bounding cube → (…, npacked) CSR coefficients."""
        d = self.sphere.extents
        flat = cube.reshape(cube.shape[:-3] + (math.prod(d),))
        return flat[..., self._pack_idx]

    def mask_cube(self, cube):
        """Zero out everything outside the cut-off sphere (cube form)."""
        return cube * self._mask.astype(cube.dtype)

    # ---------------------------------------------------------- accounting
    # flop_count/comm_stats come from Plan via the delegated stage list
    def estimated_bytes(self) -> int:
        """Stage operands plus the per-sphere pack index and mask tables —
        the tables are what makes distinct spheres expensive cache entries.
        """
        return (int(self._pack_idx.nbytes) + int(self._mask.nbytes)
                + super().estimated_bytes())

    def describe(self) -> str:
        return ("PlaneWaveFFT sphere d=%d -> grid n=%d\n" %
                (self.sphere.extents[0], self.n[0])) + self.plan.describe()


def planewave_spec(batch_axes: tuple[int, ...] = (),
                   fft_axes: tuple[int, ...] = (0,)) -> str:
    """Arrow spec for the batched sphere↔cube transform on a given grid.

    The batch dim rides ``batch_axes`` (bands — and k-points, when the
    caller stacks them), the transform dims ride ``fft_axes``: x carries
    every fft axis on the sphere side, Z on the cube side, so the staged
    schedule's all_to_alls all run over the fft axes and the batch axes
    never communicate.  ``planewave_spec()`` with no batch axes is the 1D
    layout the dft subsystem used to pin (``"b x{0} y z -> b X Y Z{0}"``).
    """
    from .dtensor import dims_string
    bspec = {"b": tuple(batch_axes)} if batch_axes else {}
    in_s = dims_string(("b", "x", "y", "z"),
                       {**bspec, "x": tuple(fft_axes)})
    out_s = dims_string(("b", "X", "Y", "Z"),
                        {**bspec, "Z": tuple(fft_axes)})
    return f"{in_s} -> {out_s}"


def cube_spec(fft_axes: tuple[int, ...] = (0,)) -> str:
    """Arrow spec for the unbatched full-cube transform (density fields).

    Only the fft axes appear — on a (batch, fft) 2D grid the cube transform
    is replicated over the batch axes (every band/k group needs the full
    density and potential anyway).
    """
    from .dtensor import dims_string
    in_s = dims_string(("x", "y", "z"), {"z": tuple(fft_axes)})
    out_s = dims_string(("X", "Y", "Z"), {"Z": tuple(fft_axes)})
    return f"{in_s} -> {out_s}"


def make_planewave_pair(grid, n: int, sphere: SphereDomain, nb: int, *,
                        backend: str = "matmul",
                        batch_axes: tuple[int, ...] = (),
                        fft_axes: tuple[int, ...] | None = None,
                        policy: ExecPolicy | None = None
                        ) -> tuple[PlaneWaveFFT, PlaneWaveFFT]:
    """(inverse, forward) plane-wave transforms sharing one data layout.

    inverse: sphere bounding-cube (b, x{F}, y, z) → real cube (b, X, Y, Z{F})
    forward: the derived mirror (``inv.inverse()``) — exact adjoint layouts,
    so `forward(inverse(c))` round-trips without extra movement, and the
    pair costs a single schedule search.  ``batch_axes`` shard the band
    batch over extra grid axes (the paper's §3.3 batch×fft 2D grids).
    """
    if fft_axes is None:
        fft_axes = tuple(a for a in range(grid.ndim) if a not in batch_axes)
    bdom = Domain((0,), (nb - 1,))
    sph = sphere
    cube = Domain((0, 0, 0), (n - 1, n - 1, n - 1))

    in_s, out_s = planewave_spec(
        tuple(batch_axes), tuple(fft_axes)).split(" -> ")
    in_i = DistTensor.create((bdom, sph), in_s, grid)
    out_i = DistTensor.create((bdom, cube), out_s, grid)
    inv = PlaneWaveFFT(sph, (n, n, n), in_i, out_i, inverse=True,
                       backend=backend, policy=policy)
    return inv, inv.inverse()
