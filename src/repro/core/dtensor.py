"""Distributed tensor descriptors — the paper's `tensor(dom, "b x{0} y z", g)`.

A dims-string names each logical dimension and annotates distribution over
processing-grid axes::

    "x{0} y z"      x distributed over grid axis 0; y, z local
    "b x{0} y{1} z" batched, 2D processing grid
    "X Y Z{0}"      output tensor distributed in z

Multiple grid axes on one dim ("x{0,1}") shard it over both, major→minor in
the order written.  The paper uses an elemental *cyclic* distribution; we use
the JAX-native *blocked* distribution (see DESIGN.md §2 for why this is the
TPU-appropriate choice and how plan-time round-robin recovers load balance
for ragged sphere data).
"""
from __future__ import annotations

import dataclasses
import re

from jax.sharding import NamedSharding, PartitionSpec as P

from .domain import Domain
from .grid import ProcGrid

_TOKEN = re.compile(r"^([A-Za-z_][A-Za-z_0-9]*)(?:\{(\d+(?:,\d+)*)\})?$")


def parse_dims(spec: str) -> tuple[tuple[str, ...], dict[str, tuple[int, ...]]]:
    """Parse a dims-string → (dim names, {dim: grid-axis indices})."""
    if "->" in spec:
        raise ValueError(
            f"{spec!r} is an arrow spec — one side expected here "
            "(use parse_transform_spec / Transform.parse for 'in -> out')")
    names: list[str] = []
    dist: dict[str, tuple[int, ...]] = {}
    for tok in spec.split():
        m = _TOKEN.match(tok)
        if not m:
            raise ValueError(f"bad dim token {tok!r} in {spec!r}")
        name, axes = m.group(1), m.group(2)
        if name in names:
            raise ValueError(f"duplicate dim {name!r} in {spec!r}")
        names.append(name)
        if axes:
            dist[name] = tuple(int(a) for a in axes.split(","))
    return tuple(names), dist


def dims_string(names, dist) -> str:
    """Inverse of ``parse_dims``: render (names, {dim: axes}) as a spec."""
    toks = []
    for nm in names:
        axes = dist.get(nm, ())
        toks.append(nm + ("{%s}" % ",".join(map(str, axes)) if axes else ""))
    return " ".join(toks)


def parse_transform_spec(spec: str):
    """Parse an arrow spec ``"b x{0} y z -> b X Y Z{0}"``.

    Returns ``((in_names, in_dist), (out_names, out_dist))``.  Dims pair up
    positionally; a dim whose name is identical on both sides is a *batch*
    dim, a renamed dim is *transformed* (the paper's lower→upper convention,
    though any renaming counts).
    """
    parts = spec.split("->")
    if len(parts) != 2:
        raise ValueError(
            f"transform spec must contain exactly one '->': {spec!r}")
    lhs, rhs = parts
    if not lhs.strip() or not rhs.strip():
        raise ValueError(f"empty side in transform spec {spec!r}")
    in_names, in_dist = parse_dims(lhs)
    out_names, out_dist = parse_dims(rhs)
    if len(in_names) != len(out_names):
        raise ValueError(
            f"rank mismatch in {spec!r}: {len(in_names)} input dims vs "
            f"{len(out_names)} output dims")
    if not any(i != o for i, o in zip(in_names, out_names)):
        raise ValueError(
            f"no transformed dims in {spec!r}: rename at least one dim "
            "(e.g. 'x -> X') to mark it transformed")
    return (in_names, in_dist), (out_names, out_dist)


@dataclasses.dataclass(frozen=True)
class DistTensor:
    """Descriptor: domains × dims-string × processing grid (paper Fig. 6/8).

    ``domains`` are composed by cross product, in order, one logical dim per
    domain *axis* — a 1D batch domain contributes dim 0, a 3D cuboid domain
    contributes three dims, mirroring the paper's `dom_in.push_back(...)`.
    """

    domains: tuple[Domain, ...]
    dims: tuple[str, ...]
    layout: dict[str, tuple[int, ...]]       # dim -> grid axes (major→minor)
    grid: ProcGrid

    @staticmethod
    def create(domains, dims_spec: str, grid: ProcGrid) -> "DistTensor":
        if isinstance(domains, Domain):
            domains = (domains,)
        names, dist = parse_dims(dims_spec)
        rank = sum(d.ndim for d in domains)
        if rank != len(names):
            raise ValueError(
                f"dims {names} rank {len(names)} != domain rank {rank}")
        for dim, axes in dist.items():
            for a in axes:
                if a >= grid.ndim:
                    raise ValueError(
                        f"dim {dim!r} references grid axis {a} but grid has "
                        f"{grid.ndim} axes")
        return DistTensor(tuple(domains), names, dist, grid)

    # ---------------------------------------------------------------- shape
    @property
    def shape(self) -> tuple[int, ...]:
        out: list[int] = []
        for d in self.domains:
            out.extend(d.extents)
        return tuple(out)

    def dim_index(self, name: str) -> int:
        return self.dims.index(name)

    def dim_size(self, name: str) -> int:
        return self.shape[self.dim_index(name)]

    # ------------------------------------------------------------- sharding
    @property
    def pspec(self) -> P:
        entries = []
        for name in self.dims:
            axes = self.layout.get(name, ())
            if not axes:
                entries.append(None)
            elif len(axes) == 1:
                entries.append(self.grid.axis_name(axes[0]))
            else:
                entries.append(tuple(self.grid.axis_name(a) for a in axes))
        return P(*entries)

    @property
    def sharding(self) -> NamedSharding:
        return NamedSharding(self.grid.mesh, self.pspec)

    @property
    def local_shape(self) -> tuple[int, ...]:
        out = []
        for name, n in zip(self.dims, self.shape):
            for a in self.layout.get(name, ()):
                s = self.grid.axis_size(a)
                if n % s:
                    raise ValueError(
                        f"dim {name} size {n} not divisible by grid axis "
                        f"{a} (size {s})")
                n //= s
            out.append(n)
        return tuple(out)
