"""Processing grids — FFTB's `grid` object, mapped onto `jax.sharding.Mesh`.

The paper creates 1D/2D/3D processing grids over an MPI communicator::

    std::vector<int> procs{16};
    grid g = grid(procs, MPI_COMM_WORLD);

Here a ProcGrid wraps a jax Mesh.  A grid can own a fresh mesh (standalone
FFT use) or *view* a subset of axes of an existing production mesh, which is
how FFTB embeds inside the training/serving runtime (e.g. the FFT grid lives
on the ("model",) axis while ("pod", "data") carry the batch).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from jax.sharding import Mesh

from .compat import abstract_mesh, make_mesh as _make_mesh


@dataclasses.dataclass(frozen=True)
class ProcGrid:
    """A 1D/2D/3D processing grid over a subset of mesh axes."""

    mesh: Mesh
    axes: tuple[str, ...]           # mesh axis names, grid dim 0..k-1

    # ---------------------------------------------------------------- build
    @staticmethod
    def create(procs: Sequence[int], axis_names: Sequence[str] | None = None
               ) -> "ProcGrid":
        """Standalone grid (paper's `grid(procs, MPI_COMM_WORLD)`)."""
        names = tuple(axis_names) if axis_names else tuple(
            f"g{i}" for i in range(len(procs)))
        return ProcGrid(_make_mesh(procs, names), names)

    @staticmethod
    def create_abstract(procs: Sequence[int],
                        axis_names: Sequence[str] | None = None
                        ) -> "ProcGrid":
        """Device-less grid for plan construction/inspection (costing a
        schedule for a 1024-GPU run from a laptop, as the paper's planner
        does) — execution requires a real grid."""
        names = tuple(axis_names) if axis_names else tuple(
            f"g{i}" for i in range(len(procs)))
        return ProcGrid(abstract_mesh(tuple(procs), names), names)

    @staticmethod
    def from_mesh(mesh: Mesh, axes: Sequence[str]) -> "ProcGrid":
        """View `axes` of an existing mesh as the processing grid."""
        for a in axes:
            if a not in mesh.axis_names:
                raise ValueError(f"axis {a!r} not in mesh {mesh.axis_names}")
        return ProcGrid(mesh, tuple(axes))

    # ---------------------------------------------------------------- query
    @property
    def ndim(self) -> int:
        return len(self.axes)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.mesh.shape[a] for a in self.axes)

    @property
    def nprocs(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_name(self, i: int) -> str:
        return self.axes[i]

    def axis_size(self, i: int) -> int:
        return self.mesh.shape[self.axes[i]]

    # ------------------------------------------------------------ placement
    def replicate(self, x):
        """Pin an eager array onto this grid's mesh, fully replicated.

        Eager ops that mix operands with different placements — a
        shard_map output sharded over a mesh axis next to a replicated or
        single-device block — can miscompute on multi-device meshes
        (observed on jax 0.4.x CPU: concatenates/contractions came out
        scaled by a mesh-axis size).  An explicit ``device_put`` onto one
        replicated sharding makes the placement unambiguous before such
        mixing; on a 1-process grid this is a no-op and results are
        bitwise unchanged.

        Under a jit trace (the fused SCF step) the same pinning becomes a
        sharding *constraint* — ``device_put`` cannot move a tracer, but
        the compiler honors the replicated placement at that point.
        """
        if self.nprocs == 1:
            return x
        import jax
        sharding = jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec())
        from . import compat
        if compat.is_tracer(x):
            return jax.lax.with_sharding_constraint(x, sharding)
        return jax.device_put(x, sharding)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        dims = "x".join(str(s) for s in self.shape)
        return f"ProcGrid({dims}, axes={self.axes})"
