"""JAX version compatibility shims.

The repo targets the mesh/shard_map APIs of recent JAX, but must also run on
older releases (e.g. 0.4.3x) where

  * ``jax.sharding.AxisType`` does not exist (meshes have no axis types —
    every axis behaves like the later ``AxisType.Auto``),
  * ``jax.make_mesh`` / ``Mesh`` take no ``axis_types`` keyword,
  * ``jax.sharding.AbstractMesh`` is constructed from ``((name, size), ...)``
    pairs instead of ``(shape, names)``,
  * ``shard_map`` lives in ``jax.experimental.shard_map`` and spells its
    replication check ``check_rep`` rather than ``check_vma``.

Everything that touches those APIs goes through this module so the rest of
the codebase can be written against one surface.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import Mesh

try:  # newer JAX
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - exercised on old JAX only
    AxisType = None

HAS_AXIS_TYPES = AxisType is not None


def _auto_axis_types(n: int):
    return (AxisType.Auto,) * n if HAS_AXIS_TYPES else None


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    shape, names = tuple(shape), tuple(names)
    if HAS_AXIS_TYPES:
        return jax.make_mesh(shape, names,
                             axis_types=_auto_axis_types(len(shape)))
    return jax.make_mesh(shape, names)


def mesh_from_devices(dev_array, names: Sequence[str]) -> Mesh:
    """``Mesh(devices, names)`` with Auto axis types where supported."""
    names = tuple(names)
    if HAS_AXIS_TYPES:
        return Mesh(dev_array, names,
                    axis_types=_auto_axis_types(len(names)))
    return Mesh(dev_array, names)


def abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """Device-less mesh for plan construction/inspection."""
    from jax.sharding import AbstractMesh
    shape, names = tuple(shape), tuple(names)
    try:
        return AbstractMesh(shape, names)
    except TypeError:  # old signature: tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(names, shape)))


def is_tracer(x) -> bool:
    """True when ``x`` is an abstract tracer (inside jit/vmap tracing).

    Placement pinning must switch from ``device_put`` (eager arrays) to
    ``with_sharding_constraint`` (tracers); ``jax.core.Tracer`` is the
    stable spelling on every supported version, with a duck-typed
    fallback should a future release drop it.
    """
    try:
        return isinstance(x, jax.core.Tracer)
    except AttributeError:  # pragma: no cover - future jax without jax.core
        return type(x).__name__.endswith("Tracer")


def shard_map(body, mesh, in_specs, out_specs):
    """``shard_map`` with the replication check off, any JAX version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
