"""Process-global LRU plan cache, eviction weighed by resident bytes.

Schedule search + index-table construction make plan building the expensive
step of every FFTB transform, and model/serving code tends to request the
same handful of transforms over and over (every SCF iteration, every decode
step).  ``PlanCache`` memoizes built plans behind a hashable key of
(spec, domains, grid, policy, ...) — ``fftb.apply``/``fftb.plan_for`` route
through the process-global instance so callers never rebuild a plan for a
transform they have already used.

Eviction is LRU on *estimated bytes* (``plan.estimated_bytes()``), not on
entry count: a large-n plane-wave plan pins megabytes of sphere index
tables while a tiny cube plan is nearly free, so counting entries evicts
the wrong things.  ``maxsize`` remains as a hard entry-count ceiling.
Shared DFT-matrix operand tables (``plan.shared_table_bytes()``, memoized
process-wide by ``local_fft.dft_matrix_device``) are refcounted by their
``(n_out, n_in, inverse)`` key, so ``resident_bytes`` charges each table
once however many cached plans reference it — byte budgets stay honest.

Thread-safe.  Builders run outside the lock (they can take seconds), so two
threads racing on the same cold key may both build — the *first* insert
wins, later builders discard their duplicate and return the cached plan
(callers may already hold references to the winner, so it must never be
replaced under them).
"""
from __future__ import annotations

import time
from collections import OrderedDict

from ..check.locks import TrackedLock, check_dispatch_hazard
from ..obs.metrics import global_metrics
from ..obs.trace import get_tracer
from .domain import Domain, SphereDomain
from .grid import ProcGrid

#: fallback cost for objects without ``estimated_bytes`` (test doubles)
_DEFAULT_ENTRY_BYTES = 4096


def _entry_cost(plan) -> tuple[int, tuple]:
    """(private bytes, shared-table items) of a would-be cache entry.

    Private bytes are billed per entry; shared tables are billed through
    the cache's refcounts.  Objects without the Plan accounting protocol
    (test doubles) fall back to a flat private cost.
    """
    try:
        tables = tuple(sorted(plan.shared_table_bytes().items()))
    except Exception:
        tables = ()
    try:
        total = int(plan.estimated_bytes())
    except Exception:
        return _DEFAULT_ENTRY_BYTES, ()
    return max(total - sum(nb for _, nb in tables), 1), tables


class PlanCache:
    """An LRU mapping from plan keys to built Plan objects."""

    def __init__(self, maxsize: int = 128, max_bytes: int = 1 << 30):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        if max_bytes < 1:
            raise ValueError("max_bytes must be >= 1")
        self.maxsize = maxsize
        self.max_bytes = int(max_bytes)
        # key -> (plan, private_bytes, shared-table items)
        self._data: OrderedDict = OrderedDict()
        # (n_out, n_in, inverse) -> [refcount, nbytes] over cached plans
        self._table_refs: dict = {}
        self._bytes = 0
        self._lock = TrackedLock("plan_cache", reentrant=True)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.builds = 0
        self.build_seconds = 0.0

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._data

    def _add_entry_bytes(self, private: int, tables: tuple) -> None:
        self._bytes += private
        for tk, nb in tables:
            ref = self._table_refs.get(tk)
            if ref is None:
                self._table_refs[tk] = [1, nb]
                self._bytes += nb                # first reference pays
            else:
                ref[0] += 1

    def _drop_entry_bytes(self, private: int, tables: tuple) -> None:
        self._bytes -= private
        for tk, nb in tables:
            ref = self._table_refs[tk]
            ref[0] -= 1
            if ref[0] == 0:                      # last reference frees
                del self._table_refs[tk]
                self._bytes -= nb

    def get_or_build(self, key, builder):
        """Return the cached plan for ``key``, building it on a miss.

        Builders run outside the lock; when two threads race on a cold
        key the first insert wins — the later builder's duplicate is
        discarded (other callers may already hold the winner) and its
        caller is served the cached plan as a hit, not a miss.
        """
        tr = get_tracer()
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                tr.instant("plan_cache.hit")
                return self._data[key][0]
        tr.instant("plan_cache.miss")
        # builders can take seconds (schedule search, executor traces) —
        # holding any lock across one is the hazard the checker hunts
        check_dispatch_hazard("plan_cache.build")
        t0 = time.perf_counter()
        with tr.span("plan_build"):
            plan = builder()
        build_s = time.perf_counter() - t0
        global_metrics().histogram("plan_cache.build_ms").record(
            build_s * 1e3)
        evicted = 0
        with self._lock:
            self.builds += 1
            self.build_seconds += build_s
            won = self._data.get(key)
            if won is not None:                  # lost a build race
                self._data.move_to_end(key)
                self.hits += 1
                return won[0]
            self.misses += 1
            private, tables = _entry_cost(plan)
            self._data[key] = (plan, private, tables)
            self._add_entry_bytes(private, tables)
            # never evict the entry just inserted, even if it alone
            # overflows the byte budget
            while len(self._data) > 1 and (
                    self._bytes > self.max_bytes
                    or len(self._data) > self.maxsize):
                _, (_, priv, tabs) = self._data.popitem(last=False)
                self._drop_entry_bytes(priv, tabs)
                self.evictions += 1
                evicted += 1
        for _ in range(evicted):
            tr.instant("plan_cache.evict")
        return plan

    def peek(self, key):
        """The cached plan for ``key``, or ``None`` — without side effects.

        No hit/miss accounting, no LRU refresh: admission control uses this
        to ask "would this dispatch need a cold build?" without distorting
        the stats the real lookup will record or promoting an entry the
        caller never used.
        """
        with self._lock:
            entry = self._data.get(key)
            return None if entry is None else entry[0]

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._table_refs.clear()
            self._bytes = 0
            self.hits = self.misses = self.evictions = 0
            self.builds = 0
            self.build_seconds = 0.0

    @property
    def resident_bytes(self) -> int:
        """Estimated bytes currently pinned by cached plans."""
        with self._lock:
            return self._bytes

    @property
    def stats(self) -> dict:
        with self._lock:
            return {"size": len(self._data), "maxsize": self.maxsize,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "builds": self.builds,
                    "build_seconds": round(self.build_seconds, 6),
                    "resident_bytes": self._bytes,
                    "max_bytes": self.max_bytes}

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        s = self.stats
        return (f"PlanCache(size={s['size']}/{s['maxsize']}, "
                f"hits={s['hits']}, misses={s['misses']})")


_GLOBAL = PlanCache()

# the legacy ad-hoc counters stay API-stable; the registry reads them
# through a probe so bench snapshots see cache behaviour without the
# cache changing shape
global_metrics().register_probe("plan_cache", lambda: _GLOBAL.stats)


def global_plan_cache() -> PlanCache:
    return _GLOBAL


# ------------------------------------------------------------------ keying
def domain_key(dom: Domain) -> tuple:
    """Hashable identity of a domain.

    SphereDomain's dataclass fields are only the bounding corners, so two
    spheres with equal bounding boxes but different radii would collide —
    include the sphere parameters explicitly.
    """
    if isinstance(dom, SphereDomain):
        return ("sphere", dom.lower, dom.upper, dom.radius, dom.center)
    return ("cuboid", dom.lower, dom.upper)


def domains_key(domains) -> tuple:
    if domains is None:
        return ()
    if isinstance(domains, Domain):
        domains = (domains,)
    return tuple(domain_key(d) for d in domains)


def grid_key(grid: ProcGrid) -> tuple:
    try:
        hash(grid.mesh)
        mesh_id = grid.mesh
    except TypeError:  # pragma: no cover - unhashable mesh implementations
        mesh_id = id(grid.mesh)
    return (mesh_id, grid.axes)
