"""Process-global LRU plan cache.

Schedule search + index-table construction make plan building the expensive
step of every FFTB transform, and model/serving code tends to request the
same handful of transforms over and over (every SCF iteration, every decode
step).  ``PlanCache`` memoizes built plans behind a hashable key of
(spec, domains, grid, policy, ...) — ``fftb.apply``/``fftb.plan_for`` route
through the process-global instance so callers never rebuild a plan for a
transform they have already used.

Thread-safe; eviction is LRU.  Builders run outside the lock (they can take
seconds), so two threads racing on the same cold key may both build — the
cache stays consistent, one of the two plans wins.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from .domain import Domain, SphereDomain
from .grid import ProcGrid


class PlanCache:
    """An LRU mapping from plan keys to built Plan objects."""

    def __init__(self, maxsize: int = 128):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = maxsize
        self._data: OrderedDict = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._data

    def get_or_build(self, key, builder):
        """Return the cached plan for ``key``, building it on a miss."""
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
        plan = builder()
        with self._lock:
            self.misses += 1
            self._data[key] = plan
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1
        return plan

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = self.misses = self.evictions = 0

    @property
    def stats(self) -> dict:
        with self._lock:
            return {"size": len(self._data), "maxsize": self.maxsize,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        s = self.stats
        return (f"PlanCache(size={s['size']}/{s['maxsize']}, "
                f"hits={s['hits']}, misses={s['misses']})")


_GLOBAL = PlanCache()


def global_plan_cache() -> PlanCache:
    return _GLOBAL


# ------------------------------------------------------------------ keying
def domain_key(dom: Domain) -> tuple:
    """Hashable identity of a domain.

    SphereDomain's dataclass fields are only the bounding corners, so two
    spheres with equal bounding boxes but different radii would collide —
    include the sphere parameters explicitly.
    """
    if isinstance(dom, SphereDomain):
        return ("sphere", dom.lower, dom.upper, dom.radius, dom.center)
    return ("cuboid", dom.lower, dom.upper)


def domains_key(domains) -> tuple:
    if domains is None:
        return ()
    if isinstance(domains, Domain):
        domains = (domains,)
    return tuple(domain_key(d) for d in domains)


def grid_key(grid: ProcGrid) -> tuple:
    try:
        hash(grid.mesh)
        mesh_id = grid.mesh
    except TypeError:  # pragma: no cover - unhashable mesh implementations
        mesh_id = id(grid.mesh)
    return (mesh_id, grid.axes)
