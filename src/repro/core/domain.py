"""Bounded domains and sphere (plane-wave) domains with CSR offset arrays.

Paper §3.2/§3.3: tensors are declared over *domains* — cuboid volumes given by
two corner points, optionally carrying an *offset array* that compresses the
z-dimension per (x, y) column (a CSR-like format produced by projecting the
cut-off sphere onto the xy-plane, as in Quantum Espresso).

All index bookkeeping here is static numpy executed at *plan build time* —
nothing in this module is traced by JAX.  The offset arrays are turned into
static gather/scatter index tables used by the pack/unpack stages.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Domain:
    """A cuboid domain given by inclusive corner points (paper Fig. 6)."""

    lower: tuple[int, ...]
    upper: tuple[int, ...]          # inclusive, as in the paper's API

    def __post_init__(self):
        if len(self.lower) != len(self.upper):
            raise ValueError("corner points must have equal rank")
        for lo, up in zip(self.lower, self.upper):
            if up < lo:
                raise ValueError(f"empty domain: {self.lower}..{self.upper}")

    @property
    def ndim(self) -> int:
        return len(self.lower)

    @property
    def extents(self) -> tuple[int, ...]:
        return tuple(u - l + 1 for l, u in zip(self.lower, self.upper))

    @property
    def npoints(self) -> int:
        n = 1
        for e in self.extents:
            n *= e
        return n


class SphereDomain(Domain):
    """A cut-off sphere inside a bounding cuboid, stored CSR-by-xy.

    ``offsets`` follows the paper's Figure 7: project the sphere points onto
    the xy-plane; for every (x, y) column inside the projection, store the
    z-extent ``[z_lo, z_hi)`` and the running offset of that column's points
    inside the packed coefficient vector.  The same offset array serves every
    wavefunction in the batch.
    """

    def __init__(self, radius: float, center: tuple[float, ...] | None = None,
                 lower: tuple[int, ...] | None = None,
                 upper: tuple[int, ...] | None = None):
        r = float(radius)
        if center is None:
            # diameter d = 2r grid points spanning [0, d-1]
            d = int(round(2 * r))
            c = (d - 1) / 2.0
            center = (c, c, c)
            lower = (0, 0, 0)
            upper = (d - 1, d - 1, d - 1)
        cx, cy, cz = center
        if lower is None:
            lower = (int(np.floor(cx - r + 0.5)), int(np.floor(cy - r + 0.5)),
                     int(np.floor(cz - r + 0.5)))
        if upper is None:
            upper = (int(np.ceil(cx + r - 0.5)), int(np.ceil(cy + r - 0.5)),
                     int(np.ceil(cz + r - 0.5)))
        super().__init__(tuple(lower), tuple(upper))
        object.__setattr__(self, "radius", r)
        object.__setattr__(self, "center", (cx, cy, cz))
        self._build_offsets()

    @staticmethod
    def from_diameter(d: int) -> "SphereDomain":
        """Sphere of diameter ``d`` grid points, bounding box [0, d-1]³."""
        return SphereDomain(radius=d / 2.0)

    # ------------------------------------------------------------------ CSR
    def _build_offsets(self) -> None:
        (xl, yl, zl), (xu, yu, zu) = self.lower, self.upper
        cx, cy, cz = self.center
        r2 = self.radius ** 2
        cols_x, cols_y, z_lo, z_hi = [], [], [], []
        for x in range(xl, xu + 1):
            for y in range(yl, yu + 1):
                h2 = r2 - (x - cx) ** 2 - (y - cy) ** 2
                if h2 < 0.0:
                    continue
                h = np.sqrt(h2)
                lo = max(zl, int(np.ceil(cz - h)))
                hi = min(zu, int(np.floor(cz + h)))
                if hi < lo:
                    continue
                cols_x.append(x); cols_y.append(y)
                z_lo.append(lo); z_hi.append(hi + 1)     # half-open
        self._col_x = np.asarray(cols_x, np.int32)
        self._col_y = np.asarray(cols_y, np.int32)
        self._z_lo = np.asarray(z_lo, np.int32)
        self._z_hi = np.asarray(z_hi, np.int32)
        lens = self._z_hi - self._z_lo
        self._row_ptr = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)

    # Public CSR view — the paper's `offsets` argument.
    @property
    def offsets(self) -> dict[str, np.ndarray]:
        return {
            "col_x": self._col_x, "col_y": self._col_y,
            "z_lo": self._z_lo, "z_hi": self._z_hi,
            "row_ptr": self._row_ptr,
        }

    @property
    def ncols(self) -> int:
        return int(self._col_x.shape[0])

    @property
    def npacked(self) -> int:
        """Number of stored points (sphere interior) — the packed length."""
        return int(self._row_ptr[-1])

    # ------------------------------------------------- static index tables
    def pack_indices(self) -> np.ndarray:
        """Flat indices into the bounding cuboid (x, y, z C-order) for every
        packed coefficient, in CSR order.  Used by unpack (scatter) / pack
        (gather) stages; built once per plan."""
        ex, ey, ez = self.extents
        (xl, yl, zl) = self.lower
        out = np.empty(self.npacked, np.int64)
        p = 0
        for c in range(self.ncols):
            x = self._col_x[c] - xl
            y = self._col_y[c] - yl
            for z in range(self._z_lo[c] - zl, self._z_hi[c] - zl):
                out[p] = (x * ey + y) * ez + z
                p += 1
        return out

    def mask(self) -> np.ndarray:
        """Boolean occupancy mask of the bounding cuboid (x, y, z)."""
        m = np.zeros(self.extents, bool)
        m.reshape(-1)[self.pack_indices()] = True
        return m


def sphere_for_cutoff(n: int, diam_frac: float = 0.5) -> SphereDomain:
    """Sphere domain for a plane-wave FFT grid of linear size ``n``.

    The conventional setup (paper Fig. 2): the FFT grid has width twice the
    sphere diameter → diameter d = n/2 (`diam_frac` = d/n, default 1/2).
    """
    return SphereDomain.from_diameter(int(n * diam_frac))
