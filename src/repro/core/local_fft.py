"""Local 1D DFT backends — the red "local computation" block of the paper.

The paper calls FFTW/cuFFT here.  Neither exists on TPU; the TPU-native
adaptation (DESIGN.md §2) expresses line DFTs as dense matmuls on the MXU,
with *rectangular* DFT matrices fusing the plane-wave zero-pad / truncation
directly into the GEMM shape:

    ifft_n(pad_{m→n}(x))   ==  iDFT_n[:, :m] @ x
    fft_n(x)[:k]           ==  DFT_n[:k, :]  @ x

Backends:
  "jnp"     jnp.fft (oracle / CPU validation; explicit pad + slice)
  "matmul"  split re/im real matmuls (MXU-shaped; what the TPU runs via XLA)
  "pallas"  the Pallas kernel in repro.kernels (interpret=True on CPU)

Normalization follows jnp.fft: forward unnormalized, inverse scaled by 1/n.
For rectangular inverse transforms the scale is 1/n_out (the padded length),
identical to `ifft(pad(x, n))`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_BACKENDS = ("jnp", "matmul", "pallas")
# crossover above which a single dense-DFT matmul stops being the right tool
# and the four-step factorization takes over (kernels/ops.py).
MATMUL_MAX_N = 2048


@functools.lru_cache(maxsize=128)
def _dft_matrix_np(n: int, inverse: bool) -> np.ndarray:
    k = np.arange(n)
    sign = 2j if inverse else -2j
    w = np.exp(sign * np.pi * np.outer(k, k) / n)
    if inverse:
        w = w / n
    return w.astype(np.complex64)


def dft_matrix(n_out: int, n_in: int, inverse: bool) -> np.ndarray:
    """Rectangular DFT operator (n_out × n_in) fusing pad or truncation.

    n_in <  n_out : inverse/forward of zero-padded input (cols sliced)
    n_in >  n_out : spectrum truncation (rows sliced of the n_in transform)
    """
    if n_in <= n_out:
        return _dft_matrix_np(n_out, inverse)[:, :n_in]
    return _dft_matrix_np(n_in, inverse)[:n_out, :]


@functools.lru_cache(maxsize=128)
def dft_matrix_device(n_out: int, n_in: int, inverse: bool):
    """Device-resident f32 (real, imag, real+imag) planes of ``dft_matrix``.

    The matmul executors split W into real planes; building them with
    ``jnp.asarray`` per call re-uploads the matrix host→device on every
    stage execution (and re-embeds it on every trace).  Caching the device
    arrays per (n_out, n_in, inverse) makes repeated stage execution — the
    SCF loop's thousands of identical line-DFT stages — transfer-free.
    The sum plane feeds the lazy executor's Gauss 3-mult product.

    ``ensure_compile_time_eval`` keeps the construction eager even when the
    first request happens inside a jit/shard_map trace — otherwise the
    cache would capture (and leak) tracers instead of device arrays.
    """
    w = dft_matrix(n_out, n_in, inverse)
    with jax.ensure_compile_time_eval():
        return (jnp.asarray(w.real), jnp.asarray(w.imag),
                jnp.asarray(w.real + w.imag))


def _move_last(x, axis):
    return jnp.moveaxis(x, axis, -1)


def _jnp_backend(x, axis, n_in, n_out, inverse):
    fn = jnp.fft.ifft if inverse else jnp.fft.fft
    if n_in <= n_out:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, n_out - n_in)
        xp = jnp.pad(x, pad)
        y = fn(xp, axis=axis)
        if inverse:
            # jnp.ifft normalizes by padded length already — matches matmul
            pass
        return y
    y = fn(x, axis=axis)
    return jnp.take(y, jnp.arange(n_out), axis=axis)


def _matmul_backend(x, axis, n_in, n_out, inverse):
    wr, wi, _ = dft_matrix_device(n_out, n_in, inverse)
    xm = _move_last(x, axis)
    xr, xi = jnp.real(xm), jnp.imag(xm)
    # y = x @ W^T with complex split into real MXU GEMMs
    yr = xr @ wr.T - xi @ wi.T
    yi = xr @ wi.T + xi @ wr.T
    y = jax.lax.complex(yr, yi)
    return jnp.moveaxis(y, -1, axis)


def _pallas_backend(x, axis, n_in, n_out, inverse):
    from repro.kernels import ops as kops
    xm = _move_last(x, axis)
    shp = xm.shape
    xf = xm.reshape(-1, n_in)
    yf = kops.dft_apply(xf, n_out=n_out, inverse=inverse)
    return jnp.moveaxis(yf.reshape(*shp[:-1], n_out), -1, axis)


def realized_backend(n_in: int, n_out: int, backend: str) -> str:
    """The backend ``local_dft`` will actually run for this line shape.

    A dense-matrix backend ("matmul" — and "pallas", whose kernel is the
    same single GEMM) requested above the ``MATMUL_MAX_N`` crossover
    *realizes* as "jnp" (the four-step factorization lives in
    ``kernels/ops.py`` and is not a line-stage backend).  Everything that
    accounts or reports per-stage work — ``dft_flops``, stage spans,
    ``describe()`` — must go through this so the books match what executed
    rather than what was requested.
    """
    if backend not in _BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if backend in ("matmul", "pallas") and max(n_in, n_out) > MATMUL_MAX_N:
        return "jnp"
    return backend


def local_dft(x, axis: int, n_out: int | None = None, *,
              inverse: bool = False, backend: str = "matmul"):
    """Apply a (possibly rectangular) DFT along ``axis`` of complex ``x``."""
    n_in = x.shape[axis]
    n_out = n_in if n_out is None else n_out
    backend = realized_backend(n_in, n_out, backend)
    x = x.astype(jnp.complex64)
    if backend == "jnp":
        return _jnp_backend(x, axis, n_in, n_out, inverse)
    if backend == "matmul":
        return _matmul_backend(x, axis, n_in, n_out, inverse)
    return _pallas_backend(x, axis, n_in, n_out, inverse)


def dft_flops(n_out: int, n_in: int, batch: int, backend: str) -> int:
    """FLOP estimate for one batched line-DFT stage (roofline/fig9 model).

    Priced at the *realized* backend: a matmul/pallas stage above the
    ``MATMUL_MAX_N`` crossover silently runs "jnp", and reporting dense
    GEMM FLOPs for it would overstate the stage ~n/log n-fold.
    """
    backend = realized_backend(n_in, n_out, backend)
    if backend == "matmul" or backend == "pallas":
        # 4 real GEMMs, 2·m·n MACs each → 8·m·n real FLOPs per line... use
        # 8 flops per complex MAC: y(n_out) = W(n_out×n_in) x
        return 8 * n_out * n_in * batch
    # split-radix style estimate
    n = max(n_out, n_in)
    return int(5 * n * np.log2(max(n, 2))) * batch
