"""ExecPolicy — declarative execution policy for FFTB plans.

Replaces the stringly ``mode="lazy_bf16"`` call-site switches: a plan carries
a default policy, any call may override it, and ``plan.tune(x)`` benchmarks
the candidate policies and pins the fastest one on the plan.

  mode           "eager" (interleaved complex, transposes materialized) or
                 "lazy"  (split re/im planes, permutation applied once at
                 exit — the §Perf executor)
  compute_dtype  matmul operand dtype on the lazy path ("float32" or
                 "bfloat16"; accumulation stays f32 either way)
  check_shapes   validate call-time input shape against the plan's input
                 descriptor (turn off inside hot traced code)
  backend        preferred line-DFT backend for plans built under this
                 policy (None = let the builder default, conventionally
                 "matmul"; "pallas" routes the sphere hot path through the
                 fused sphere-pack kernels).  A *preference*, resolved at
                 plan-build boundaries (e.g. PlaneWaveBasis) — an explicit
                 ``backend=`` argument always wins.

The dataclass is frozen/hashable so policies can key the process-global
PlanCache.
"""
from __future__ import annotations

import dataclasses

MODES = ("eager", "lazy")
COMPUTE_DTYPES = ("float32", "bfloat16")
BACKENDS = ("jnp", "matmul", "pallas")

# legacy mode= strings accepted at call sites, mapped to policies
_LEGACY_MODES = {
    "eager": ("eager", "float32"),
    "lazy": ("lazy", "float32"),
    "lazy_bf16": ("lazy", "bfloat16"),
}


@dataclasses.dataclass(frozen=True)
class ExecPolicy:
    mode: str = "eager"
    compute_dtype: str = "float32"
    check_shapes: bool = True
    backend: str | None = None

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"mode {self.mode!r} not in {MODES} (legacy strings like "
                f"'lazy_bf16' go through ExecPolicy.from_mode)")
        if self.compute_dtype not in COMPUTE_DTYPES:
            raise ValueError(
                f"compute_dtype {self.compute_dtype!r} not in "
                f"{COMPUTE_DTYPES}")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(
                f"backend {self.backend!r} not in {BACKENDS}")

    @staticmethod
    def from_mode(mode: "str | ExecPolicy", *,
                  check_shapes: bool = True) -> "ExecPolicy":
        """Accept a legacy mode string ('eager'/'lazy'/'lazy_bf16')."""
        if isinstance(mode, ExecPolicy):
            return mode
        if mode not in _LEGACY_MODES:
            raise ValueError(f"unknown execution mode {mode!r}; expected one "
                             f"of {tuple(_LEGACY_MODES)}")
        m, dt = _LEGACY_MODES[mode]
        return ExecPolicy(mode=m, compute_dtype=dt, check_shapes=check_shapes)

    @property
    def legacy_mode(self) -> str:
        """The old call-site string naming this policy's executor."""
        if self.mode == "lazy" and self.compute_dtype == "bfloat16":
            return "lazy_bf16"
        return self.mode

    def jax_compute_dtype(self):
        import jax.numpy as jnp
        return jnp.bfloat16 if self.compute_dtype == "bfloat16" else \
            jnp.float32


#: candidates plan.tune() races against each other
TUNE_CANDIDATES = (
    ExecPolicy(mode="eager"),
    ExecPolicy(mode="lazy"),
    ExecPolicy(mode="lazy", compute_dtype="bfloat16"),
)
