"""FftPlan — stitches local-compute and data-movement stages (paper Fig. 4).

Given input/output DistTensors and the set of transformed dims, the planner
emits an alternating sequence of

  * ``FFTStage``   — local (possibly rectangular) line DFTs on a dim that the
                     current layout keeps fully local, and
  * ``MoveStage``  — one ``all_to_all`` over a single grid axis, moving that
                     axis between two dims (a distributed transpose),

reproducing slab-pencil (1 move on a 1D grid), pencil-pencil-pencil (2 moves
on a 2D grid) and volumetric (3D grid) schedules from the declared
distributions alone.  The executed function is one ``shard_map`` over the
grid's mesh axes; XLA fuses pack/rotate layout changes into the collectives
(the paper's hand-written CUDA codelets).

``Plan`` is the common base of ``FftPlan`` and ``PlaneWaveFFT``: execution
policy resolution, tuning, and the flop/comm accounting shared by both.
Every plan can *derive* its mirror transforms — ``plan.inverse()`` and
``plan.adjoint()`` reverse the stage list (each stage knows its own mirror)
instead of running a second schedule search.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from functools import cached_property

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compat
from . import layout as L
from ..obs.metrics import global_metrics
from ..obs.trace import get_tracer
from .dtensor import DistTensor
from .local_fft import dft_flops, local_dft, realized_backend
from .policy import TUNE_CANDIDATES, ExecPolicy


@dataclasses.dataclass(frozen=True)
class FFTStage:
    dim: str
    index: int                   # position in the logical dim order
    n_in: int
    n_out: int
    inverse: bool
    backend: str

    def apply(self, x):
        return local_dft(x, self.index, self.n_out, inverse=self.inverse,
                         backend=self.backend)

    def mirrored(self) -> "FFTStage":
        """The stage of the derived inverse/adjoint plan.

        A square stage mirrors to its exact inverse (DFT_n ↔ iDFT_n).  A
        rectangular pad-fused stage (d→n) mirrors to the truncating stage
        (n→d) — the identity holds on the retained subspace, which is
        exactly the plane-wave sphere contract.
        """
        return FFTStage(self.dim, self.index, self.n_out, self.n_in,
                        not self.inverse, self.backend)

    @property
    def transform_size(self) -> int:
        """The full DFT length N the (possibly sliced) matrix comes from."""
        return max(self.n_in, self.n_out)

    @property
    def realized_backend(self) -> str:
        """The backend this stage actually runs (``local_dft`` silently
        downgrades dense backends above the MATMUL_MAX_N crossover) —
        what flop accounting and stage spans must report."""
        return realized_backend(self.n_in, self.n_out, self.backend)


@dataclasses.dataclass(frozen=True)
class MoveStage:
    axis_name: str               # mesh axis
    axis_size: int
    src: str
    dst: str
    src_index: int
    dst_index: int

    def apply(self, x):
        return jax.lax.all_to_all(
            x, self.axis_name, split_axis=self.dst_index,
            concat_axis=self.src_index, tiled=True)

    def mirrored(self) -> "MoveStage":
        """The opposite distributed transpose (all_to_all is a permutation,
        so the mirror is both its inverse and its adjoint)."""
        return MoveStage(self.axis_name, self.axis_size, self.dst, self.src,
                         self.dst_index, self.src_index)


class Plan:
    """Common protocol + shared accounting of FFTB plans.

    Concrete plans provide ``tin``/``tout``/``grid``/``dims``/``stages`` and
    ``_execute``; the base supplies policy resolution, ``tune()``, and the
    stage-walking flop/comm accounting.
    """

    tin: DistTensor
    tout: DistTensor
    policy: ExecPolicy

    # ----------------------------------------------------------- execution
    def __call__(self, x, *, policy: ExecPolicy | None = None):
        pol = self.resolve_policy(policy=policy)
        if pol.check_shapes and tuple(x.shape) != self.tin.shape:
            raise ValueError(f"input shape {x.shape} != {self.tin.shape}")
        tr = get_tracer()
        if tr.enabled and not compat.is_tracer(x):
            # spans only wrap real dispatches: under jit tracing the
            # wall clock would time trace construction, not execution
            return self._execute_traced(x, pol, tr)
        return self._execute(x, pol)

    def _execute_traced(self, x, pol: ExecPolicy, tr):
        """Execution with a span around the dispatch (device-synced)."""
        with tr.span(f"transform:{type(self).__name__}",
                     shape=list(self.tin.shape), mode=pol.mode) as sp:
            return sp.sync(self._execute(x, pol))

    def resolve_policy(self, *,
                       policy: ExecPolicy | None = None) -> ExecPolicy:
        """The call-time policy: an explicit ``policy=`` wins, otherwise
        the plan's default.  (The legacy call-site ``mode=`` string shim
        was removed with the positional ``fftb`` signature; legacy
        strings still convert via ``ExecPolicy.from_mode`` at config
        boundaries, e.g. CLI flags.)"""
        return policy if policy is not None else self.policy

    def _execute(self, x, pol: ExecPolicy):
        raise NotImplementedError

    def tune(self, x, *, candidates=TUNE_CANDIDATES, warmup: int = 1,
             iters: int = 3) -> ExecPolicy:
        """Benchmark candidate policies on ``x`` and pin the fastest.

        Returns the winning policy (also set as the plan's default, so
        subsequent plain ``plan(x)`` calls use it).
        """
        best, best_t = None, None
        for cand in candidates:
            pol = dataclasses.replace(
                cand, check_shapes=self.policy.check_shapes)
            for _ in range(warmup):
                jax.block_until_ready(self(x, policy=pol))
            t0 = time.perf_counter()
            for _ in range(iters):
                # block inside the timed region: the clock must stop
                # only after the device drained, or tune() would rank
                # candidates by dispatch latency
                jax.block_until_ready(self(x, policy=pol))
            dt = (time.perf_counter() - t0) / iters
            if best_t is None or dt < best_t:
                best, best_t = pol, dt
        self.policy = best
        m = global_metrics()
        m.counter("fftb.tunes").inc()
        m.histogram("fftb.tune_best_us").record(best_t * 1e6)
        # memoized mirrors inherited the pre-tune policy — keep the pair
        # in sync, as a freshly derived mirror would be
        for attr in ("_inverse_memo", "_adjoint_memo"):
            memo = getattr(self, attr, None)
            if memo is not None:
                memo.policy = best
        return best

    # ------------------------------------------------------------- mirrors
    def inverse(self) -> "Plan":
        """The mirror transform tout→tin, derived by reversing stages (no
        second schedule search).  Exact inverse for square transforms; for
        rectangular (pad/truncate) stages it is the mirror on the retained
        subspace.

        Memoized, with the mirror back-linked: repeated calls return the
        same object and ``plan.inverse().inverse() is plan`` — so a plan
        pair held in the PlanCache is derived once process-wide.  The
        mirror carries the policy current at derivation time (``tune()``
        re-syncs the pair); assign ``mirror.policy`` to diverge.
        """
        memo = getattr(self, "_inverse_memo", None)
        if memo is None:
            memo = self._derive_inverse()
            memo._inverse_memo = self
            self._inverse_memo = memo
        return memo

    def adjoint(self) -> "Plan":
        """The conjugate-transpose operator tout→tin, same derived stage
        list as ``inverse()`` with the DFT normalization factors flipped
        (adjoint of unnormalized DFT_N is N·iDFT_N).  Memoized and
        back-linked like ``inverse()``."""
        memo = getattr(self, "_adjoint_memo", None)
        if memo is None:
            memo = self._derive_adjoint()
            memo._adjoint_memo = self
            self._adjoint_memo = memo
        return memo

    def _derive_inverse(self) -> "Plan":
        raise NotImplementedError

    def _derive_adjoint(self) -> "Plan":
        raise NotImplementedError

    # ---------------------------------------------------------- accounting
    def private_bytes(self) -> int:
        """Bytes owned by this plan alone — descriptors, traced executors,
        and (in subclasses) the sphere pack/mask or ragged-batch tables.
        Never shared with other plans, so the cache bills them per entry."""
        return 4096

    def shared_table_bytes(self) -> dict[tuple, int]:
        """Device bytes of the ``dft_matrix_device`` operand tables the
        plan's FFT stages reference, keyed by ``(n_out, n_in, inverse)``.

        The tables are memoized process-wide (``local_fft.dft_matrix_device``
        is an lru_cache), so two plans — or two stages of one plan — with
        the same key share one device allocation.  The PlanCache refcounts
        these keys so ``resident_bytes`` charges each table once, however
        many cached plans reference it.
        """
        out: dict[tuple, int] = {}
        for st in self.stages:
            if isinstance(st, FFTStage):
                out.setdefault((st.n_out, st.n_in, st.inverse),
                               3 * 4 * st.n_in * st.n_out)
        return out

    def estimated_bytes(self) -> int:
        """Rough resident bytes this plan pins while cached, considered
        alone: private bytes plus each *distinct* DFT-matrix table it
        references.  The PlanCache weighs entries by this instead of
        counting them — large-n plans hold big operand tables while tiny
        plans are nearly free — and subtracts tables already pinned by
        other cached plans (see ``shared_table_bytes``)."""
        return self.private_bytes() + sum(self.shared_table_bytes().values())

    def flop_count(self) -> int:
        total = 0
        sizes = {d: n for d, n in zip(self.tin.dims, self.tin.shape)}
        for st in self.stages:
            if isinstance(st, FFTStage):
                batch = math.prod(sizes[d] for d in self.dims if d != st.dim)
                total += dft_flops(st.n_out, st.n_in, batch, st.backend)
                sizes[st.dim] = st.n_out
        return total

    def comm_stats(self, itemsize: int = 8) -> list[dict]:
        """Per-MoveStage communication volume (bytes sent per device)."""
        return self._comm_stats_for(self.stages, itemsize)

    def _comm_stats_for(self, stages, itemsize: int = 8) -> list[dict]:
        out = []
        sizes = {d: n for d, n in zip(self.tin.dims, self.tin.shape)}
        lay = L.normalize(self.tin.layout)
        grid_shape = self.grid.shape
        for st in stages:
            if isinstance(st, FFTStage):
                sizes[st.dim] = st.n_out
                continue
            local_elems = math.prod(
                L.local_size(d, sizes[d], lay, grid_shape)
                for d in self.dims)
            p = st.axis_size
            out.append({
                "axis": st.axis_name, "procs": p,
                "bytes_per_device": local_elems * itemsize * (p - 1) // p,
                "move": f"{st.src}->{st.dst}",
            })
            # replay the move on the tracking layout
            ax = [a for a in range(len(grid_shape))
                  if self.grid.axis_name(a) == st.axis_name][0]
            lay = L.apply_move(lay, L.Move(ax, st.src, st.dst))
        return out

    def describe(self) -> str:
        lines = [f"{type(self).__name__} over {self.grid}: "
                 f"{self.tin.dims} {self.tin.layout} -> "
                 f"{self.tout.dims} {self.tout.layout}"]
        for st in self.stages:
            if isinstance(st, FFTStage):
                kind = "iDFT" if st.inverse else "DFT"
                rb = st.realized_backend
                be = st.backend if rb == st.backend else \
                    f"{st.backend}->{rb}"
                lines.append(f"  {kind}[{st.dim}] {st.n_in}->{st.n_out} "
                             f"({be})")
            else:
                lines.append(f"  a2a[{st.axis_name}] {st.src}->{st.dst}")
        scale = getattr(self, "scale", 1.0)
        if scale != 1.0:
            lines.append(f"  scale ×{scale:g}")
        return "\n".join(lines)


class FftPlan(Plan):
    """A compiled-able distributed multi-dimensional (batched) FFT."""

    #: process-wide count of schedule searches — lets tests (and the plan
    #: cache) assert that derived/cached plans never re-plan.
    searches = 0

    #: process-wide count of distributed-transform dispatches (one per
    #: executor invocation; under jit tracing that is once per traced
    #: transform, so a jitted SCF step counts its transforms at trace
    #: time and then never again) — instrumentation for "exactly two
    #: distributed transforms per stacked sweep" assertions.
    executions = 0

    def __init__(self, tin: DistTensor, tout: DistTensor,
                 fft_dims: list[tuple[str, str]], *, inverse: bool = False,
                 backend: str = "matmul", policy: ExecPolicy | None = None,
                 _stages: list | None = None, _scale: float = 1.0):
        if tin.grid.mesh is not tout.grid.mesh:
            raise ValueError("input and output tensors live on different "
                             "meshes")
        self.tin, self.tout, self.grid = tin, tout, tin.grid
        self.is_inverse, self.backend = inverse, backend
        self.policy = policy if policy is not None else ExecPolicy()
        self.scale = _scale
        self.dims = tin.dims
        self.fft_pairs = list(fft_dims)

        # map output dim names onto input dim names (batch dims by position)
        o2i = {o: i for i, o in fft_dims}
        in_batch = [d for d in tin.dims if d not in {i for i, _ in fft_dims}]
        out_batch = [d for d in tout.dims if d not in o2i]
        if len(in_batch) != len(out_batch):
            raise ValueError("batch dims of input/output do not match")
        o2i.update(dict(zip(out_batch, in_batch)))
        if [o2i[d] for d in tout.dims] != list(tin.dims):
            raise ValueError(
                "output dims must correspond to input dims in order "
                f"(got {tout.dims} vs {tin.dims})")

        self._final_layout = L.normalize(
            {o2i[d]: ax for d, ax in tout.layout.items()})
        if _stages is not None:
            self.stages = list(_stages)     # derived plan: no search
        else:
            self._search()

    # ------------------------------------------------------------ planning
    def _search(self) -> None:
        """Pick the transform order minimizing communicated bytes.

        Rectangular (padding) transforms grow dims, so *when* a dim is
        transposed matters: the paper's staged-padding win is precisely
        scheduling the all-to-all before the moved dims are padded.  The
        planner enumerates transform orders (≤ 3! for 3D), prices each
        schedule with the comm model, and keeps the cheapest — the
        "framework decides on the most suited implementation" behaviour
        of the paper's intermediate block.
        """
        FftPlan.searches += 1
        fft_in = [i for i, _ in self.fft_pairs]
        dim_pos = {d: k for k, d in enumerate(self.dims)}
        innermost = max(fft_in, key=lambda d: dim_pos[d])
        best = None
        for perm in itertools.permutations(fft_in):
            try:
                stages = self._build(list(perm))
            except RuntimeError:
                continue
            cost = sum(s["bytes_per_device"]
                       for s in self._comm_stats_for(stages))
            moves = sum(isinstance(s, MoveStage) for s in stages)
            # comm-equal tie-break: transform the innermost (contiguous)
            # dim first — the paper's canonical z-first order, and the
            # stage the fused sphere-pack kernels can absorb.  Matters on
            # single-device grids where every schedule prices to zero.
            key = (cost, moves, perm.index(innermost))
            if best is None or key < best[0]:
                best = (key, stages)
        if best is None:
            raise RuntimeError("no feasible FFT schedule found")
        self.stages = best[1]

    def _build(self, order: list[str]) -> list:
        grid_shape = self.grid.shape
        sizes = {d: n for d, n in zip(self.tin.dims, self.tin.shape)}
        # n_out per input fft dim
        pair_out = {i: self.tout.dim_size(o) for i, o in self.fft_pairs}
        lay = L.normalize(self.tin.layout)
        stages: list[FFTStage | MoveStage] = []
        done: set[str] = set()
        fft_in_dims = [i for i, _ in self.fft_pairs]
        batch_dims = [d for d in self.dims if d not in fft_in_dims]
        idx = {d: k for k, d in enumerate(self.dims)}

        def emit_move(axis: int, src: str, dst: str):
            stages.append(MoveStage(
                self.grid.axis_name(axis), grid_shape[axis], src, dst,
                idx[src], idx[dst]))

        def local(d):
            return L.local_size(d, sizes[d], lay, grid_shape)

        def pick_park(d: str, axis: int) -> str:
            """Destination for an axis that must leave fft dim ``d``."""
            cands = [t for t in self.dims if t != d
                     and local(t) % grid_shape[axis] == 0]
            if not cands:
                raise RuntimeError(
                    f"cannot free dim {d}: no dim can absorb grid axis "
                    f"{axis} (layout {lay}, sizes {sizes})")

            def score(t):
                tgt = self._final_layout.get(t, ())
                cur = lay.get(t, ())
                wants = (len(cur) < len(tgt) and tgt[: len(cur)] == cur
                         and tgt[len(cur)] == axis)
                return (
                    0 if wants else 1,                       # final home first
                    0 if (t in done or t in batch_dims) else 1,  # no re-free
                    -local(t),                               # roomiest
                )
            return min(cands, key=score)

        for d in order:
            while lay.get(d, ()):
                axis = lay[d][-1]
                dst = pick_park(d, axis)
                emit_move(axis, d, dst)
                lay = L.apply_move(lay, L.Move(axis, d, dst))
            stages.append(FFTStage(d, idx[d], sizes[d], pair_out[d],
                                   self.is_inverse, self.backend))
            sizes[d] = pair_out[d]
            done.add(d)

        for mv in L.plan_redistribution(lay, self._final_layout, sizes,
                                        grid_shape):
            emit_move(mv.axis, mv.src, mv.dst)
            lay = L.apply_move(lay, mv)
        return stages

    # ------------------------------------------------------------- mirrors
    def _mirror(self, scale: float) -> "FftPlan":
        # stage dim names live in the input-side namespace; the mirrored
        # plan's input is our output, so rename positionally (x → X) or
        # the mirror's accounting would key sizes/layouts by unknown dims
        ren = dict(zip(self.tin.dims, self.tout.dims))
        stages = []
        for st in reversed(self.stages):
            m = st.mirrored()
            if isinstance(m, FFTStage):
                m = dataclasses.replace(m, dim=ren[m.dim])
            else:
                m = dataclasses.replace(m, src=ren[m.src], dst=ren[m.dst])
            stages.append(m)
        pairs = [(o, i) for i, o in self.fft_pairs]
        return FftPlan(self.tout, self.tin, pairs,
                       inverse=not self.is_inverse, backend=self.backend,
                       policy=self.policy, _stages=stages, _scale=scale)

    def _derive_inverse(self) -> "FftPlan":
        return self._mirror(1.0 / self.scale if self.scale != 1.0 else 1.0)

    def _derive_adjoint(self) -> "FftPlan":
        # adjoint of sliced DFT_N is N · sliced iDFT_N (and vice versa):
        # the mirrored stage list times the product of flipped norms.
        scale = self.scale
        for st in self.stages:
            if isinstance(st, FFTStage):
                scale *= (1.0 / st.transform_size if st.inverse
                          else float(st.transform_size))
        return self._mirror(scale)

    # ----------------------------------------------------------- execution
    def _raw_apply(self, x):
        for st in self.stages:
            x = st.apply(x)
        if self.scale != 1.0:
            x = x * jnp.asarray(self.scale, x.dtype)
        return x

    def _raw_apply_lazy(self, x, compute_dtype=jnp.float32):
        """Lazy-permutation, split-complex executor (§Perf optimization).

        The eager path pays, per stage, two moveaxis transposes plus a
        complex interleave/deinterleave around the real matmuls — ~6× the
        useful HBM traffic on the paper's 256³ workload.  Here (a) the
        transform axis is contracted IN PLACE with dot_general and the
        output axis lands at the end (a logical permutation we only undo
        once, at exit), and (b) data flows as separate (re, im) f32 planes
        end-to-end, so nothing ever interleaves.  Same stages, same
        collectives — only the local data movement differs.
        """
        from .local_fft import dft_matrix_device
        perm = list(range(x.ndim))        # perm[i] = logical dim at pos i
        xr = jnp.real(x).astype(compute_dtype)
        xi = jnp.imag(x).astype(compute_dtype)
        for st in self.stages:
            if isinstance(st, FFTStage):
                pos = perm.index(st.index)
                wr, wi, ws = dft_matrix_device(st.n_out, st.n_in,
                                               st.inverse)
                wr = wr.astype(compute_dtype)
                wi = wi.astype(compute_dtype)
                dn = (((pos,), (1,)), ((), ()))

                def dot(a, b):
                    return jax.lax.dot_general(
                        a, b, dn, preferred_element_type=jnp.float32)
                # Gauss 3-multiplication complex product: 3 real GEMMs
                # instead of 4 (−25% MXU work and operand traffic):
                #   m1 = xr·wr, m2 = xi·wi, m3 = (xr+xi)·(wr+wi)
                #   yr = m1 − m2, yi = m3 − m1 − m2
                m1 = dot(xr, wr)
                m2 = dot(xi, wi)
                m3 = dot((xr + xi).astype(compute_dtype),
                         ws.astype(compute_dtype))
                xr = (m1 - m2).astype(compute_dtype)
                xi = (m3 - m1 - m2).astype(compute_dtype)
                perm = [p for i, p in enumerate(perm) if i != pos] \
                    + [st.index]
            else:
                sp = perm.index(st.dst_index)
                cp = perm.index(st.src_index)
                xr = jax.lax.all_to_all(xr, st.axis_name, split_axis=sp,
                                        concat_axis=cp, tiled=True)
                xi = jax.lax.all_to_all(xi, st.axis_name, split_axis=sp,
                                        concat_axis=cp, tiled=True)
        out_axes = [perm.index(i) for i in range(len(perm))]
        xr = jnp.transpose(xr, out_axes)
        xi = jnp.transpose(xi, out_axes)
        if self.scale != 1.0:
            s = jnp.asarray(self.scale, jnp.float32)
            xr, xi = xr.astype(jnp.float32) * s, xi.astype(jnp.float32) * s
        return jax.lax.complex(xr.astype(jnp.float32),
                               xi.astype(jnp.float32))

    def _sharded(self, pol: ExecPolicy):
        mesh = self.grid.mesh
        if pol.mode == "eager":
            body = self._raw_apply
        else:
            dtype = pol.jax_compute_dtype()

            def body(x):
                return self._raw_apply_lazy(x, compute_dtype=dtype)
        fn = compat.shard_map(body, mesh, self.tin.pspec, self.tout.pspec)
        return jax.jit(fn)

    @cached_property
    def _fn_cache(self):
        return {}

    @property
    def _sharded_fn(self):
        return self._fn_for(ExecPolicy())

    def _fn_for(self, pol: ExecPolicy):
        key = (pol.mode, pol.compute_dtype)
        fn = self._fn_cache.get(key)
        if fn is None:
            fn = self._fn_cache[key] = self._sharded(pol)
        return fn

    def _execute(self, x, pol: ExecPolicy):
        FftPlan.executions += 1
        return self._fn_for(pol)(x)

    # -------------------------------------------------- traced execution
    def _pspec_for_layout(self, lay) -> P:
        """PartitionSpec of this plan's dims under layout ``lay`` —
        the same rendering ``DistTensor.pspec`` does, for the
        *intermediate* layouts between stages."""
        entries = []
        for d in self.dims:
            axes = lay.get(d, ())
            if not axes:
                entries.append(None)
            elif len(axes) == 1:
                entries.append(self.grid.axis_name(axes[0]))
            else:
                entries.append(tuple(self.grid.axis_name(a) for a in axes))
        return P(*entries)

    @cached_property
    def _stage_executors(self):
        """One jitted ``shard_map`` per stage, with span metadata.

        The normal executor is ONE ``jit(shard_map(...))`` over the whole
        stage list — individual stages cannot be timed inside it.  When
        per-stage tracing is on, execution runs stage-by-stage instead:
        each stage gets its own small sharded callable whose in/out
        PartitionSpecs come from replaying the layout moves (exactly as
        ``_comm_stats_for`` prices them), and MoveStage spans carry the
        comm model's ``bytes_per_device``/``procs`` tags so traces hold
        measured *and* modeled comm side by side.
        """
        mesh = self.grid.mesh
        lay = L.normalize(self.tin.layout)
        grid_shape = self.grid.shape
        comm = iter(self.comm_stats())
        out = []
        for st in self.stages:
            in_spec = self._pspec_for_layout(lay)
            if isinstance(st, FFTStage):
                kind = "idft" if st.inverse else "dft"
                meta = {"name": f"{kind}[{st.dim}] {st.n_in}->{st.n_out}",
                        "kind": "fft", "backend": st.realized_backend}
                out_spec = in_spec
            else:
                stats = next(comm)
                ax = [a for a in range(len(grid_shape))
                      if self.grid.axis_name(a) == st.axis_name][0]
                lay = L.apply_move(lay, L.Move(ax, st.src, st.dst))
                out_spec = self._pspec_for_layout(lay)
                meta = {"name": f"a2a[{st.axis_name}] {st.src}->{st.dst}",
                        "kind": "a2a", "procs": stats["procs"],
                        "model_bytes_per_device":
                            stats["bytes_per_device"]}
            fn = jax.jit(compat.shard_map(st.apply, mesh, in_spec,
                                          out_spec))
            out.append((fn, meta))
        return out

    def _execute_traced(self, x, pol: ExecPolicy, tr):
        FftPlan.executions += 1
        name = ("ifft" if self.is_inverse else "fft") \
            + f"{len(self.fft_pairs)}d"
        with tr.span(f"plan:{name}", shape=list(self.tin.shape),
                     mode=pol.mode, stages=len(self.stages)) as sp:
            if not tr.per_stage:
                return sp.sync(self._fn_for(pol)(x))
            # stage-by-stage: eager per-stage apply (the lazy executor
            # interleaves stages and cannot be split), one span each
            for fn, meta in self._stage_executors:
                attrs = {k: v for k, v in meta.items() if k != "name"}
                with tr.span(meta["name"], **attrs) as ssp:
                    x = ssp.sync(fn(x))
            if self.scale != 1.0:
                x = x * jnp.asarray(self.scale, x.dtype)
            return sp.sync(x)


global_metrics().register_probe(
    "fftb", lambda: {"executions": FftPlan.executions,
                     "searches": FftPlan.searches})
