"""Layout algebra — the planner that turns distribution changes into
`all_to_all` steps (the paper's yellow "intermediate" block).

A *layout* maps each logical dim to the stack of grid axes sharding it,
major→minor.  The invariant that keeps blocked distributions coherent is
that shard stacks are only pushed/popped at the **minor** end: moving the
minor-most axis of dim ``u`` onto dim ``v`` is exactly one
``jax.lax.all_to_all(..., split_axis=v, concat_axis=u, tiled=True)`` inside a
``shard_map`` body, and preserves global block order on both dims.
"""
from __future__ import annotations

import dataclasses

Layout = dict[str, tuple[int, ...]]      # dim -> grid axis indices


@dataclasses.dataclass(frozen=True)
class Move:
    """Move grid axis ``axis`` from minor end of ``src`` onto ``dst``."""
    axis: int
    src: str
    dst: str


def normalize(layout: Layout) -> Layout:
    return {k: tuple(v) for k, v in layout.items() if v}


def local_size(dim: str, global_size: int, layout: Layout,
               grid_shape: tuple[int, ...]) -> int:
    n = global_size
    for a in layout.get(dim, ()):
        n //= grid_shape[a]
    return n


def apply_move(layout: Layout, mv: Move) -> Layout:
    out = {k: list(v) for k, v in layout.items()}
    src = out.get(mv.src, [])
    if not src or src[-1] != mv.axis:
        raise ValueError(f"{mv} illegal: {mv.axis} is not minor-most of "
                         f"{mv.src} in {layout}")
    src.pop()
    out.setdefault(mv.dst, []).append(mv.axis)
    return normalize({k: tuple(v) for k, v in out.items()})


def plan_redistribution(cur: Layout, target: Layout, sizes: dict[str, int],
                        grid_shape: tuple[int, ...],
                        max_steps: int = 64) -> list[Move]:
    """Greedy sequence of Moves taking ``cur`` to ``target``.

    Strategy: repeatedly (1) pop axes that sit on a dim where the target
    disagrees, parking them on a dim that *wants* them next (i.e. the dim's
    current stack is a proper prefix of its target and the next wanted axis
    matches); (2) if no direct placement exists, park on the dim with the
    largest local size (usually the batch dim) and retry.  Terminates for
    every pattern used by slab/pencil/volumetric plans; guarded by
    ``max_steps``.
    """
    cur = normalize(cur)
    target = normalize(target)
    moves: list[Move] = []

    def wants_next(dim: str, axis: int, lay: Layout) -> bool:
        t = target.get(dim, ())
        c = lay.get(dim, ())
        return len(c) < len(t) and t[: len(c)] == c and t[len(c)] == axis

    def divisible(dim: str, axis: int, lay: Layout) -> bool:
        return local_size(dim, sizes[dim], lay, grid_shape) \
            % grid_shape[axis] == 0

    steps = 0
    while cur != target:
        steps += 1
        if steps > max_steps:
            raise RuntimeError(
                f"redistribution did not converge: {cur} -> {target}")
        progressed = False
        # 1. direct placements: a minor axis someone wants right now
        for src, stack in list(cur.items()):
            if not stack:
                continue
            axis = stack[-1]
            if target.get(src, ())[: len(stack)] == tuple(stack):
                continue                    # already a prefix of target: keep
            for dst in sizes:
                if dst != src and wants_next(dst, axis, cur) \
                        and divisible(dst, axis, cur):
                    mv = Move(axis, src, dst)
                    cur = apply_move(cur, mv)
                    moves.append(mv)
                    progressed = True
                    break
            if progressed:
                break
        if progressed:
            continue
        # 2. park a blocking minor axis on the roomiest legal dim
        cand = None
        for src, stack in list(cur.items()):
            if not stack:
                continue
            if target.get(src, ()) == tuple(stack):
                continue
            axis = stack[-1]
            parks = [d for d in sizes
                     if d != src and divisible(d, axis, cur)
                     and not wants_next(d, stack[-1] if False else axis, cur)]
            parks = [d for d in parks
                     if local_size(d, sizes[d], cur, grid_shape)
                     % grid_shape[axis] == 0]
            if parks:
                best = max(parks, key=lambda d: local_size(
                    d, sizes[d], cur, grid_shape))
                cand = Move(axis, src, best)
                break
        if cand is None:
            raise RuntimeError(
                f"redistribution stuck: {cur} -> {target} (sizes {sizes})")
        cur = apply_move(cur, cand)
        moves.append(cand)
    return moves
