"""fftb() — the user-facing constructor around one arrow-spec string.

The modern entry points::

    fx = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g)     # build a plan
    y  = fftb.apply("b x{0} y z -> b X Y Z{0}", x,             # cached apply
                    domains=(b, dom), grid=g)
    tr = Transform.parse("b x{0} y z -> b X Y Z{0}")           # reusable spec

Dims pair up positionally across the arrow; a dim with the same name on both
sides is a batch dim, a renamed dim ("x -> X") is transformed.  Transformed
sizes are inferred from the declared domains (same-size transforms) unless
``sizes=``/``out_domains=`` override them — a SphereDomain among the input
domains selects the plane-wave staged-padding path automatically.

``fftb.apply``/``fftb.plan_for`` memoize built plans in a process-global LRU
``PlanCache`` keyed by (spec, domains, grid, policy, ...), so model/serving
code never re-runs the schedule search for a transform it has already used.

The paper's positional C++-style signature
``fftb(sizes, to, "X Y Z", ti, "x y z", g)`` was deprecated in PR 1 and has
been **removed** after the two-PR grace window; calling ``fftb`` with
anything but an arrow-spec string raises a ``TypeError`` carrying the
migration recipe (fold the two dims-strings into one arrow spec, pass
domains instead of hand-built ``DistTensor``s).
"""
from __future__ import annotations

import dataclasses

from .cache import PlanCache, domains_key, global_plan_cache, grid_key
from .domain import Domain, SphereDomain
from .dtensor import DistTensor, dims_string, parse_transform_spec
from .plan import FftPlan, Plan
from .planewave import PlaneWaveFFT
from .policy import ExecPolicy


def _as_domains(domains) -> tuple[Domain, ...]:
    if isinstance(domains, Domain):
        return (domains,)
    return tuple(domains)


@dataclasses.dataclass(frozen=True)
class Transform:
    """A parsed arrow spec — the declarative half of a plan.

    Hashable (layouts stored as sorted item tuples), so a Transform can be
    parsed once at module import and reused to build plans against many
    (domains, grid) combinations.
    """

    spec: str
    in_dims: tuple[str, ...]
    in_layout: tuple[tuple[str, tuple[int, ...]], ...]
    out_dims: tuple[str, ...]
    out_layout: tuple[tuple[str, tuple[int, ...]], ...]

    @staticmethod
    def parse(spec: str) -> "Transform":
        (in_names, in_dist), (out_names, out_dist) = \
            parse_transform_spec(spec)
        return Transform(spec, in_names, tuple(sorted(in_dist.items())),
                         out_names, tuple(sorted(out_dist.items())))

    # ------------------------------------------------------------- queries
    @property
    def rank(self) -> int:
        return len(self.in_dims)

    @property
    def fft_pairs(self) -> list[tuple[str, str]]:
        """(input dim, output dim) for every transformed dim, in order."""
        return [(i, o) for i, o in zip(self.in_dims, self.out_dims)
                if i != o]

    @property
    def batch_dims(self) -> tuple[str, ...]:
        return tuple(i for i, o in zip(self.in_dims, self.out_dims)
                     if i == o)

    @property
    def in_spec(self) -> str:
        return dims_string(self.in_dims, dict(self.in_layout))

    @property
    def out_spec(self) -> str:
        return dims_string(self.out_dims, dict(self.out_layout))

    # ------------------------------------------------------------ building
    def _infer_out_domains(self, domains: tuple[Domain, ...],
                           sizes: dict[str, int]) -> tuple[Domain, ...]:
        """Output domains: input domains with transformed extents replaced.

        A SphereDomain whose dims are transformed opens up to its cuboid
        (the inverse plane-wave direction); producing a sphere *output*
        (forward truncation) needs explicit ``out_domains`` — or just
        derive it as ``plan.inverse()``.
        """
        fft_in = {i for i, _ in self.fft_pairs}
        out: list[Domain] = []
        cursor = 0
        for dom in domains:
            names = self.in_dims[cursor:cursor + dom.ndim]
            cursor += dom.ndim
            touched = any(n in fft_in for n in names)
            if not touched:
                out.append(dom)
                continue
            extents = tuple(sizes.get(n, e)
                            for n, e in zip(names, dom.extents))
            if isinstance(dom, SphereDomain) or extents != dom.extents:
                out.append(Domain((0,) * dom.ndim,
                                  tuple(e - 1 for e in extents)))
            else:
                out.append(dom)
        return tuple(out)

    def _norm_sizes(self, sizes) -> dict[str, int]:
        pairs = self.fft_pairs
        if sizes is None:
            return {}
        if isinstance(sizes, dict):
            bad = set(sizes) - {i for i, _ in pairs}
            if bad:
                raise ValueError(f"sizes name non-transformed dims {bad}")
            return dict(sizes)
        sizes = tuple(sizes)
        if len(sizes) != len(pairs):
            raise ValueError(
                f"{len(sizes)} sizes for {len(pairs)} transformed dims")
        return {i: n for (i, _), n in zip(pairs, sizes)}

    def build(self, domains, grid, *, out_domains=None, sizes=None,
              inverse: bool = False, backend: str = "matmul",
              policy: ExecPolicy | None = None) -> Plan:
        """Construct the plan for this spec over concrete domains/grid."""
        domains = _as_domains(domains)
        rank = sum(d.ndim for d in domains)
        if rank != self.rank:
            raise ValueError(
                f"spec {self.spec!r} has rank {self.rank} but domains have "
                f"rank {rank}")
        size_map = self._norm_sizes(sizes)
        if out_domains is None:
            out_domains = self._infer_out_domains(domains, size_map)
        else:
            out_domains = _as_domains(out_domains)
        tin = DistTensor.create(domains, self.in_spec, grid)
        tout = DistTensor.create(out_domains, self.out_spec, grid)
        pairs = self.fft_pairs
        for i, o in pairs:
            if i in size_map and tout.dim_size(o) != size_map[i]:
                raise ValueError(
                    f"output dim {o} extent {tout.dim_size(o)} != "
                    f"size {size_map[i]}")
        sphere = [d for t in (tin, tout) for d in t.domains
                  if isinstance(d, SphereDomain)]
        if sphere:
            n = tuple(max(tin.dim_size(i), tout.dim_size(o))
                      for i, o in pairs)
            return PlaneWaveFFT(sphere[0], n, tin, tout, inverse=inverse,
                                backend=backend, pairs=pairs, policy=policy)
        return FftPlan(tin, tout, pairs, inverse=inverse, backend=backend,
                       policy=policy)


# ----------------------------------------------------------------- builders
def _plan_cache_key(spec: str, domains, grid, *, out_domains, sizes,
                    inverse, backend, policy) -> tuple:
    if isinstance(sizes, dict):
        sizes = tuple(sorted(sizes.items()))
    elif sizes is not None:
        sizes = tuple(sizes)
    return (spec, domains_key(domains), grid_key(grid),
            domains_key(out_domains), sizes, inverse, backend, policy)


def plan_for(spec: str, *, domains, grid, out_domains=None, sizes=None,
             inverse: bool = False, backend: str = "matmul",
             policy: ExecPolicy | None = None,
             cache: PlanCache | None = None) -> Plan:
    """Cached plan lookup — builds (schedule search and all) only on miss."""
    cache = cache if cache is not None else global_plan_cache()
    key = _plan_cache_key(spec, domains, grid, out_domains=out_domains,
                          sizes=sizes, inverse=inverse, backend=backend,
                          policy=policy)

    def _build():
        # coded preflight diagnostics before any plan work — runs on
        # cache misses only, so the hot (hit) path pays nothing
        from ..check.preflight import check_transform
        check_transform(spec, domains=domains, grid=grid, sizes=sizes,
                        out_domains=out_domains)
        return Transform.parse(spec).build(
            domains, grid, out_domains=out_domains, sizes=sizes,
            inverse=inverse, backend=backend, policy=policy)

    return cache.get_or_build(key, _build)


def apply(spec: str, x, *, domains, grid, out_domains=None, sizes=None,
          inverse: bool = False, backend: str = "matmul",
          policy: ExecPolicy | None = None, cache: PlanCache | None = None):
    """One-shot cached transform: ``fftb.apply(spec, x, domains=, grid=)``.

    Repeated calls with the same (spec, domains, grid, policy) reuse the
    cached plan — no second schedule search, no shard_map re-trace.
    """
    plan = plan_for(spec, domains=domains, grid=grid,
                    out_domains=out_domains, sizes=sizes, inverse=inverse,
                    backend=backend, policy=policy, cache=cache)
    return plan(x)


# ------------------------------------------------------------- entry point
def fftb(spec, *args, **kwargs):
    """Create a distributed (batched) multi-dimensional Fourier transform.

    One form — arrow spec plus domains/grid::

        fftb("b x{0} y z -> b X Y Z{0}", domains=(b, dom), grid=g)

    Returns a Plan (FftPlan or PlaneWaveFFT) exposing ``__call__``,
    ``inverse()``, ``adjoint()``, ``tune()``, ``describe()``,
    ``flop_count()`` and ``comm_stats()``.

    The paper's positional C++-style signature
    ``fftb(sizes, tout, "X Y Z", tin, "x y z", g)`` was deprecated in
    PR 1 and removed after the grace window — see the TypeError below
    (and README "Migrating from the positional form") for the recipe.
    """
    if not isinstance(spec, str):
        raise TypeError(
            "the positional fftb(sizes, tout, out_dims, tin, in_dims, "
            "grid) signature has been removed; fold the dims-strings into "
            "one arrow spec and pass domains instead of DistTensors: "
            "fftb('x{0} y z -> X Y Z{0}', domains=dom, grid=g, "
            "sizes=...) — see README 'Migrating from the positional "
            "form'")
    return Transform.parse(spec).build(*args, **kwargs)


def _preflight(target, **kwargs):
    """``fftb.preflight(...)`` — static feasibility diagnostics.

    A spec string routes to the transform checks, a config dict to the
    basis/service checks; returns the
    :class:`~repro.check.diagnostics.Diagnostic` list, never raises.
    Lazy import: ``repro.check.preflight`` depends on ``repro.core``.
    """
    from ..check.preflight import preflight
    return preflight(target, **kwargs)


fftb.apply = apply
fftb.plan_for = plan_for
fftb.cache = global_plan_cache
fftb.preflight = _preflight
