"""fftb() — the user-facing constructor, mirroring the paper's C++ API::

    fftb fx = fftb(sizes, to, "X Y Z", ti, "x y z", g);

The dims-strings passed here name the *transformed* dims of each tensor (in
order); dims of the tensors not named are batch dims.  If the input tensor's
trailing domain is a SphereDomain, the plane-wave path (staged padding fused
into rectangular DFTs) is selected automatically — the paper's Fig. 8 usage.
"""
from __future__ import annotations

from .domain import SphereDomain
from .dtensor import DistTensor
from .plan import FftPlan
from .planewave import PlaneWaveFFT


def fftb(sizes, tout: DistTensor, out_dims: str, tin: DistTensor,
         in_dims: str, grid=None, *, inverse: bool = False,
         backend: str = "matmul"):
    """Create a distributed (batched) multi-dimensional Fourier transform.

    Returns a callable plan object (FftPlan or PlaneWaveFFT) exposing
    ``__call__``, ``describe()``, ``flop_count()`` and ``comm_stats()``.
    """
    grid = grid or tin.grid
    in_names = tuple(in_dims.split())
    out_names = tuple(out_dims.split())
    if len(in_names) != len(out_names):
        raise ValueError("in/out transform dims must pair up")
    sizes = tuple(sizes)
    if len(sizes) != len(in_names):
        raise ValueError("one size per transformed dim")

    sphere = any(isinstance(d, SphereDomain) for d in tin.domains)
    if sphere:
        return PlaneWaveFFT.from_tensors(sizes, tout, out_names, tin,
                                         in_names, grid, inverse=inverse,
                                         backend=backend)
    for nm, n in zip(out_names, sizes):
        if tout.dim_size(nm) != n:
            raise ValueError(
                f"output dim {nm} extent {tout.dim_size(nm)} != size {n}")
    pairs = list(zip(in_names, out_names))
    return FftPlan(tin, tout, pairs, inverse=inverse, backend=backend)
