"""FFTB core — flexible distributed multi-dimensional FFTs (the paper's
contribution), plus the plane-wave sphere transform and spectral model ops."""

from .domain import Domain, SphereDomain, sphere_for_cutoff
from .dtensor import DistTensor, parse_dims
from .fft import fftb
from .grid import ProcGrid
from .local_fft import dft_matrix, local_dft
from .plan import FftPlan
from .planewave import PlaneWaveFFT, make_planewave_pair
from .spectral import fft_conv, fourier_mixer

__all__ = [
    "Domain", "SphereDomain", "sphere_for_cutoff", "DistTensor",
    "parse_dims", "fftb", "ProcGrid", "dft_matrix", "local_dft", "FftPlan",
    "PlaneWaveFFT", "make_planewave_pair", "fft_conv", "fourier_mixer",
]
