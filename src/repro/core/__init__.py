"""FFTB core — flexible distributed multi-dimensional FFTs (the paper's
contribution), plus the plane-wave sphere transform and spectral model ops."""

from .cache import PlanCache, global_plan_cache
from .domain import Domain, SphereDomain, sphere_for_cutoff
from .dtensor import (DistTensor, dims_string, parse_dims,
                      parse_transform_spec)
from .fft import Transform, fftb
from .grid import ProcGrid
from .local_fft import dft_matrix, local_dft
from .plan import FftPlan, Plan
from .planewave import (PlaneWaveFFT, StackedPlaneWaveFFT, cube_spec,
                        kpoint_sphere, make_planewave_pair,
                        make_stacked_planewave_pair, padded_kinetic_table,
                        padded_pack_tables, planewave_spec,
                        segment_padding_fraction, segment_spheres,
                        sphere_gvectors, sphere_kinetic_row)
from .policy import ExecPolicy
from .spectral import fft_conv, fourier_mixer

__all__ = [
    "Domain", "SphereDomain", "sphere_for_cutoff", "DistTensor",
    "parse_dims", "parse_transform_spec", "dims_string", "Transform",
    "fftb", "ProcGrid", "dft_matrix", "local_dft", "Plan", "FftPlan",
    "PlaneWaveFFT", "StackedPlaneWaveFFT", "kpoint_sphere",
    "make_planewave_pair",
    "make_stacked_planewave_pair", "padded_kinetic_table",
    "padded_pack_tables", "planewave_spec", "cube_spec",
    "segment_padding_fraction", "segment_spheres",
    "sphere_gvectors", "sphere_kinetic_row",
    "ExecPolicy", "PlanCache",
    "global_plan_cache", "fft_conv", "fourier_mixer",
]
