"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --preset cpu-ci --steps 50

Presets size the run to the host: `cpu-ci` trains the reduced config on
whatever devices exist; `full` uses the published config on the production
mesh (real accelerators).  Fault-tolerance knobs (checkpoint dir/interval,
auto-resume, grad compression) are flags.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model_zoo import build
from repro.optim.adamw import AdamWConfig
from repro.sharding import ctx, rules
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--preset", default="cpu-ci",
                    choices=["cpu-ci", "100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fixed-batch", action="store_true",
                    help="repeat step-0 batch (memorization curve for CI)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "cpu-ci":
        cfg = cfg.reduced()
        mesh = make_host_mesh((1, 1))
    elif args.preset == "100m":
        # ~100M-param member of the same family
        cfg = dataclasses.replace(
            cfg.reduced(), name=cfg.name + "-100m", n_layers=12,
            d_model=768, n_heads=12, n_kv=max(cfg.n_kv and 4, 0),
            head_dim=64, d_ff=3072, vocab=32000)
        mesh = make_host_mesh((1, 1))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    bundle = build(cfg)
    extra = {}
    if cfg.family == "vlm":
        import jax.numpy as jnp
        extra["image_embeds"] = jnp.zeros(
            (args.global_batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        import jax.numpy as jnp
        extra["frames"] = jnp.zeros(
            (args.global_batch, cfg.enc_seq, cfg.d_model), jnp.float32)

    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, microbatches=args.microbatches,
        compress_grads=args.compress_grads)
    dcfg = DataConfig(vocab=cfg.vocab, seq=args.seq,
                      global_batch=args.global_batch)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    with ctx.use(mesh, rules.batch_axis(mesh, args.global_batch)):
        trainer = Trainer(bundle, opt, tcfg, dcfg, mesh=mesh,
                          extra_batch=extra)
        if args.fixed_batch:
            trainer.pipeline.batch_at = \
                lambda step, _f=type(trainer.pipeline).batch_at, \
                p=trainer.pipeline: _f(p, 0)
        trainer.run()
    losses = [h["loss"] for h in trainer.history]
    if losses:
        print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f} "
              f"({len(losses)} steps)")
    return trainer


if __name__ == "__main__":
    main()
