import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces the artifacts the roofline analysis reads:
  * compiled.memory_analysis()  — proves the cell fits 16 GB/chip,
  * compiled.cost_analysis()    — HLO FLOPs / bytes accessed,
  * collective bytes parsed from the optimized HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute).

Results append incrementally to experiments/dryrun.json so interrupted
sweeps resume.  The paper's own workload (fftb-paper: batched plane-wave
FFT 256³, sphere d=128, 256 bands) runs through the same harness.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
  python -m repro.launch.dryrun --paper
"""
import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ARCH_IDS, applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.model_zoo import build
from repro.sharding import ctx, rules
from repro.train.train_step import make_train_step, init_opt_state
from repro.optim.adamw import AdamWConfig

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun.json")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")


def collective_bytes(hlo: str) -> dict[str, int]:
    """Per-device *operand* bytes of every collective in optimized HLO.

    Optimized HLO prints operands by name only, so sizes are derived from
    the RESULT type: all-reduce/all-to-all/collective-permute results equal
    their operands; all-gather operands are result/participants;
    reduce-scatter operands are result×participants.  Participant counts
    come from replica_groups (explicit {{...}} or iota [G,P]<=[N] form).
    """
    out: dict[str, int] = {c: 0 for c in _COLL}
    shape_re = re.compile(r"(\w+)\[([0-9,]*)\]")
    line_re = re.compile(
        r"=\s*((?:\([^=]*?\))|(?:\S+))\s+"
        r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
        r"collective-permute)(-start)?\(")
    for line in hlo.splitlines():
        m = line_re.search(line)
        if not m:
            continue
        restype, op, start = m.group(1), m.group(2), m.group(3)
        total = 0
        for dt, dims in shape_re.findall(restype):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        if start and restype.startswith("("):
            total //= 2          # async start returns (operand, result)
        # participants
        p = 1
        g = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
        if g:
            p = len(g.group(1).split(","))
        else:
            g = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
            if g:
                p = int(g.group(2))
        if op == "all-gather" and p:
            total //= p
        elif op == "reduce-scatter":
            total *= p
        out[op] += total
    return out


# --------------------------------------------------------------- inputs
def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.batch, shape.seq
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            # image tokens replace part of the sequence (stub embeddings)
            n_img = cfg.n_img_tokens
            batch = {"tokens": sds((B, S - n_img), jnp.int32),
                     "labels": sds((B, S - n_img), jnp.int32),
                     "image_embeds": sds((B, n_img, cfg.d_model),
                                         jnp.bfloat16)}
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model),
                                  jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            n_img = cfg.n_img_tokens
            batch = {"tokens": sds((B, S - n_img), jnp.int32),
                     "image_embeds": sds((B, n_img, cfg.d_model),
                                         jnp.bfloat16)}
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model),
                                  jnp.bfloat16)
        return batch
    # decode: one new token against a cache of length S
    return {"tokens": sds((B, 1), jnp.int32),
            "lengths": sds((B,), jnp.int32)}


def _eval_shape_tree(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def _shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------ accounting
def account_cell(arch: str, shape_name: str, mesh, *, verbose=True):
    """Honest per-device FLOP/byte/collective totals.

    XLA's cost_analysis counts while-loop (lax.scan) bodies ONCE, so the
    scanned-layer cells above under-report by ~the layer count.  Here the
    same cell is lowered twice with all scans UNROLLED at depths L=1 and
    L=2 (hybrid: 1 and 2 groups); scan bodies are homogeneous, so every
    cost is exactly linear in depth and extrapolates to the full depth:
        cost(L) = cost(1) + (cost(2) − cost(1))·(L − 1).
    Microbatching is folded to 1 for this pass (same token count → same
    matmul work; only the accumulate-adds differ, negligible).
    """
    import dataclasses as _dc
    from repro.models import flags as _flags
    cfg = get_config(arch)
    if cfg.family == "hybrid":
        plen = len(cfg.block_pattern)
        depths = (plen, 2 * plen)
        l_full = (cfg.n_layers // plen)
        unit = plen
    else:
        depths = (1, 2)
        l_full = cfg.n_layers
        unit = 1
    recs = []
    for L in depths:
        cfg_l = _dc.replace(cfg, n_layers=L,
                            enc_layers=min(cfg.enc_layers, L) if
                            cfg.enc_layers else 0)
        with _flags.unrolled():
            recs.append(lower_cell(arch, shape_name, mesh, verbose=False,
                                   cfg_override=cfg_l))
    r1, r2 = recs
    steps = l_full - 1

    def extra(key):
        if isinstance(r1[key], dict):
            return {k: r1[key][k] + (r2[key][k] - r1[key][k]) * steps
                    for k in r1[key]}
        return r1[key] + (r2[key] - r1[key]) * steps

    out = {
        "arch": arch, "shape": shape_name, "mesh": r1["mesh"],
        "n_devices": r1["n_devices"],
        "flops": extra("flops"),
        "bytes_accessed": extra("bytes_accessed"),
        "collective_bytes": extra("collective_bytes"),
        "collective_total": extra("collective_total"),
        "depths": list(depths), "l_full": l_full,
        "method": "unrolled-L1L2-extrapolation",
    }
    if cfg.family == "hybrid" and cfg.n_layers % len(cfg.block_pattern):
        # 38 = 12 groups + 2 tail rec layers: scale by true/extrapolated
        scale = cfg.n_layers / (l_full * len(cfg.block_pattern))
        for k in ("flops", "bytes_accessed", "collective_total"):
            out[k] *= scale
        out["collective_bytes"] = {k: v * scale
                                   for k, v in out["collective_bytes"].items()}
        out["tail_scale"] = scale
    if verbose:
        print(f"[{out['mesh']}] acct {arch} × {shape_name}: "
              f"flops={out['flops']:.3e} bytes={out['bytes_accessed']:.3e} "
              f"coll={out['collective_total']:.3e}", flush=True)
    return out


# ----------------------------------------------------------------- cells
def lower_cell(arch: str, shape_name: str, mesh, *, verbose=True,
               cfg_override=None, mb_override=None):
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    bundle = build(cfg)
    key = jax.random.PRNGKey(0)
    batch_axes = rules.batch_axis(mesh, shape.batch)
    # sequence parallelism for the long-activation cells (train/prefill)
    seq_axis = "model" if shape.kind in ("train", "prefill") else None
    _cm = ctx.use(mesh, batch_axes, seq_axis)
    _cm.__enter__()
    params_sds = jax.eval_shape(bundle.init, key)
    pspecs = rules.param_specs(params_sds, mesh)
    pshard = _shardings(pspecs, mesh)
    batch_sds_all = input_specs(arch, shape_name)
    bspec = rules.data_specs(cfg, shape, mesh)
    bspec = {k: v for k, v in bspec.items() if k in batch_sds_all}
    for k in batch_sds_all:
        bspec.setdefault(k, P(*([batch_axes]
                                + [None] * (batch_sds_all[k].ndim - 1))))
    bshard = _shardings(bspec, mesh)
    t0 = time.perf_counter()

    if shape.kind == "train":
        # memory-reduced (bf16) optimizer states once f32 m/v would exceed
        # ~40% of HBM: params×10B/dev > 6.5 GiB → switch (8-bit-Adam-style)
        pbytes = sum(x.size for x in jax.tree.leaves(params_sds))
        opt_dtype = jnp.bfloat16 if pbytes * 10 / mesh.size > 6.5 * 2**30 \
            else jnp.float32
        opt_sds = jax.eval_shape(
            lambda p: init_opt_state(p, dtype=opt_dtype), params_sds)
        ospecs = rules.param_specs(opt_sds, mesh)
        oshard = _shardings(ospecs, mesh)
        # microbatching: keep ≤ ~16k tokens per device per microbatch —
        # the standard activation-memory lever at scale
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dp *= mesh.shape[a]
        b_loc = max(shape.batch // dp, 1)
        # wider models carry proportionally bigger activations per token;
        # MoE intermediates scale with top_k·d_ff (≈6× a dense MLP on dbrx)
        tok_budget = 16384 if cfg.d_model < 8192 else 4096
        if cfg.family == "moe" and cfg.top_k * cfg.d_ff > 4 * cfg.d_model:
            tok_budget = 4096
        mb = max(1, (b_loc * shape.seq) // tok_budget)
        while b_loc % mb:
            mb -= 1
        if mb_override is not None:
            mb = mb_override
        step = make_train_step(bundle, AdamWConfig(), mesh, donate=False,
                               microbatches=mb)
        batch_sds = input_specs(arch, shape_name)
        fn = jax.jit(lambda p, o, b: step(p, o, b),
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None))
        lowered = fn.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        cache_sds = jax.eval_shape(
            lambda: bundle.init_cache(shape.batch, shape.seq, jnp.bfloat16))
        cspecs = rules.cache_specs(cfg, shape.batch, mesh, cache_sds)
        cshard = _shardings(cspecs, mesh)
        batch_sds = input_specs(arch, shape_name)

        def fn(params, batch, cache):
            return bundle.prefill(params, batch, cache)

        lowered = jax.jit(
            fn, in_shardings=(pshard, bshard, cshard),
            out_shardings=(None, cshard)).lower(
            params_sds, batch_sds, cache_sds)
    else:  # decode
        capacity = shape.seq
        cache_sds = jax.eval_shape(
            lambda: bundle.init_cache(shape.batch, capacity, jnp.bfloat16))
        cspecs = rules.cache_specs(cfg, shape.batch, mesh, cache_sds)
        cshard = _shardings(cspecs, mesh)
        b = rules.batch_axis(mesh, shape.batch)
        tok_shard = _shardings({"tokens": P(b, None), "lengths": P(b)},
                               mesh)
        ins = input_specs(arch, shape_name)

        def fn(params, tokens, cache, lengths):
            return bundle.decode(params, tokens, cache, lengths)

        # cache is donated (in-place update), exactly as the serving
        # engine runs it — halves the measured cache footprint
        lowered = jax.jit(
            fn, in_shardings=(pshard, tok_shard["tokens"], cshard,
                              tok_shard["lengths"]),
            out_shardings=(None, cshard),
            donate_argnums=(2,)).lower(
            params_sds, ins["tokens"], cache_sds, ins["lengths"])

    _cm.__exit__(None, None, None)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "n_devices": mesh.size,
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "collective_total": float(sum(coll.values())),
        "mem": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "code": mem.generated_code_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
        },
        "peak_bytes_per_device": mem.argument_size_in_bytes
        + mem.output_size_in_bytes + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes,
        "t_lower_s": t_lower, "t_compile_s": t_compile,
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} × {shape_name}: "
              f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"coll={rec['collective_total']:.3e} "
              f"peak={rec['peak_bytes_per_device']/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    return rec


def lower_paper_workload(mesh, *, verbose=True, backend="matmul",
                         variant="planewave"):
    """The paper's Fig. 9 workload as a dry-run cell.

    variant: planewave (staged pad, batched) | padded (full-cube baseline).
    """
    from repro.configs.fftb_paper import CONFIG as PC
    from repro.core import (Domain, ProcGrid, SphereDomain, DistTensor,
                            FftPlan, make_planewave_pair)
    fft_axes = tuple(i for i, a in enumerate(mesh.axis_names)
                     if a == "model")
    batch_axes = tuple(i for i, a in enumerate(mesh.axis_names)
                       if a != "model")
    grid = ProcGrid.from_mesh(mesh, mesh.axis_names)
    t0 = time.perf_counter()
    if variant == "planewave":
        sph = SphereDomain.from_diameter(PC.diameter)
        inv, _ = make_planewave_pair(grid, PC.n, sph, PC.nb,
                                     backend=backend,
                                     batch_axes=batch_axes,
                                     fft_axes=fft_axes)
        plan = inv.plan
        d = PC.diameter
        in_shape = (PC.nb, d, d, d)
    else:
        n, nb = PC.n, PC.nb
        bdom = Domain((0,), (nb - 1,))
        cube = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
        bspec = "{%s}" % ",".join(str(a) for a in batch_axes)
        fspec = "{%s}" % ",".join(str(a) for a in fft_axes)
        ti = DistTensor.create((bdom, cube), f"b{bspec} x{fspec} y z", grid)
        to = DistTensor.create((bdom, cube), f"B{bspec} X Y Z{fspec}", grid)
        plan = FftPlan(ti, to, [("x", "X"), ("y", "Y"), ("z", "Z")],
                       inverse=True, backend=backend)
        in_shape = (nb, n, n, n)
    sds = jax.ShapeDtypeStruct(in_shape, jnp.complex64)
    lowered = plan._sharded_fn.lower(sds)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": f"fftb-paper-{variant}", "shape": f"n{PC.n}-d{PC.diameter}-b{PC.nb}",
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "n_devices": mesh.size,
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "collective_total": float(sum(coll.values())),
        "model_comm_bytes": list(plan.comm_stats()),
        "mem": {"argument": mem.argument_size_in_bytes,
                "output": mem.output_size_in_bytes,
                "temp": mem.temp_size_in_bytes},
        "peak_bytes_per_device": mem.argument_size_in_bytes
        + mem.output_size_in_bytes + mem.temp_size_in_bytes,
        "t_lower_s": t_lower, "t_compile_s": t_compile,
        "plan": plan.describe(),
    }
    if verbose:
        print(f"[{rec['mesh']}] {rec['arch']}: flops={rec['flops']:.3e} "
              f"coll={rec['collective_total']:.3e} "
              f"peak={rec['peak_bytes_per_device']/2**30:.2f}GiB "
              f"(compile {t_compile:.0f}s)", flush=True)
    return rec


# ------------------------------------------------------------------ main
def _load():
    try:
        with open(RESULTS) as f:
            return json.load(f)
    except FileNotFoundError:
        return {}


def _store(db):
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    tmp = RESULTS + ".tmp"
    with open(tmp, "w") as f:
        json.dump(db, f, indent=1)
    os.replace(tmp, RESULTS)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--paper-variant", default="planewave")
    ap.add_argument("--account", action="store_true",
                    help="unrolled accounting pass (honest scan FLOPs)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    db = _load()
    failures = []

    def run(arch, shape_name, mname, mesh):
        key = f"{arch}|{shape_name}|{mname}"
        if args.account:
            key += "|acct"
        ok, why = applicable(get_config(arch), SHAPES[shape_name])
        if not ok:
            db[key] = {"arch": arch, "shape": shape_name, "mesh": mname,
                       "skipped": why}
            _store(db)
            print(f"SKIP {key}: {why}")
            return
        if key in db and not db[key].get("error") and not args.force:
            print(f"cached {key}")
            return
        try:
            fn = account_cell if args.account else lower_cell
            db[key] = fn(arch, shape_name, mesh)
        except Exception as e:  # record the failure, keep sweeping
            db[key] = {"arch": arch, "shape": shape_name, "mesh": mname,
                       "error": f"{type(e).__name__}: {e}"}
            failures.append(key)
            print(f"FAIL {key}: {e}", flush=True)
        _store(db)

    if args.paper:
        for mname, mesh in meshes:
            key = f"fftb-paper-{args.paper_variant}|{mname}"
            if key in db and not db[key].get("error") and not args.force:
                print(f"cached {key}")
                continue
            db[key] = lower_paper_workload(mesh,
                                           variant=args.paper_variant)
            _store(db)
        return

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for mname, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                run(arch, shape_name, mname, mesh)
    if failures:
        print(f"\n{len(failures)} failures: {failures}")
        raise SystemExit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
