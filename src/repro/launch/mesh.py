"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  Single pod: 16×16 = 256 chips
("data", "model"); multi-pod: 2×16×16 = 512 chips ("pod", "data", "model")
— "pod" is the DCN-like axis (pure DP + hierarchical gradient reduction).
"""
from __future__ import annotations

import jax

from repro.core.compat import mesh_from_devices


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, found {len(devices)} — "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before any "
            "jax import")
    import numpy as np
    dev_array = np.asarray(devices[:ndev]).reshape(shape)
    return mesh_from_devices(dev_array, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over whatever devices exist (CPU tests/examples)."""
    import numpy as np
    ndev = 1
    for s in shape:
        ndev *= s
    dev = np.asarray(jax.devices()[:ndev]).reshape(shape)
    return mesh_from_devices(dev, axes)
