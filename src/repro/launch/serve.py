"""Serving launcher: batched requests through the continuous-batching
engine on a reduced config (CPU) or the production mesh (accelerators).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.model_zoo import build
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, slots=args.slots, capacity=args.capacity)
    eng.load(params)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=8,
                                        dtype=np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    for r in reqs:
        print(f"req {r.rid}: prompt {r.prompt.tolist()} -> {r.out}")
    print(f"served {len(reqs)} requests in {eng.steps} decode steps "
          f"({args.slots} slots, continuous batching)")
    return reqs


if __name__ == "__main__":
    main()
