"""Fault-tolerant training loop.

Production behaviors implemented (and exercised by tests/examples):
  * auto-resume from the latest committed checkpoint,
  * periodic async checkpoints + a final blocking one,
  * SIGTERM/SIGINT → immediate checkpoint then clean exit (preemption),
  * per-step wall-time EMA straggler monitor (flags hosts/steps > k·σ;
    on a real pod this feeds the backup-worker reassignment in
    data.pipeline.Pipeline.reassign),
  * deterministic data: batch = f(seed, step) — restart-safe by design.
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline
from repro.optim.adamw import AdamWConfig
from repro.sharding import rules
from .train_step import init_opt_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    microbatches: int = 1
    compress_grads: bool = False
    straggler_sigma: float = 3.0
    seed: int = 0


class StragglerMonitor:
    """EMA of step time; flags outliers (straggler mitigation hook)."""

    def __init__(self, sigma: float = 3.0, decay: float = 0.9):
        self.sigma, self.decay = sigma, decay
        self.mean = None
        self.var = 0.0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.mean is None:
            self.mean = dt
            return False
        slow = bool(dt > self.mean + self.sigma
                    * max(np.sqrt(self.var), 1e-4))
        if slow:
            self.flagged.append((step, dt))
        d = dt - self.mean
        self.mean += (1 - self.decay) * d
        self.var = self.decay * (self.var + (1 - self.decay) * d * d)
        return slow


class Trainer:
    def __init__(self, bundle, opt_cfg: AdamWConfig, tcfg: TrainerConfig,
                 data_cfg: DataConfig, mesh=None, extra_batch=None):
        self.bundle, self.tcfg = bundle, tcfg
        self.mesh = mesh
        self.pipeline = Pipeline(data_cfg)
        self.extra_batch = extra_batch or {}
        self.step_fn = make_train_step(
            bundle, opt_cfg, mesh, microbatches=tcfg.microbatches,
            compress=tcfg.compress_grads)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.monitor = StragglerMonitor(tcfg.straggler_sigma)
        self._stop = False
        self.history: list[dict] = []

    # ------------------------------------------------------------ state
    def _save(self, step, params, opt_state, block=False):
        specs = {"params": rules.param_specs(params, self.mesh),
                 "opt": rules.param_specs(opt_state, self.mesh)}
        self.ckpt.save(step, {"params": params, "opt": opt_state},
                       specs, block=block)

    def _restore_or_init(self, key):
        latest = self.ckpt.latest_step()
        if latest is not None:
            step, tree = self.ckpt.restore(mesh=self.mesh)
            return step, tree["params"], tree["opt"]
        params = self.bundle.init(key)
        opt = init_opt_state(params, compress=self.tcfg.compress_grads)
        return 0, params, opt

    # ------------------------------------------------------------- run
    def run(self, key=None):
        key = jax.random.PRNGKey(self.tcfg.seed) if key is None else key
        start, params, opt_state = self._restore_or_init(key)

        def handle(sig, frame):
            self._stop = True
        old = [signal.signal(s, handle)
               for s in (signal.SIGTERM, signal.SIGINT)]
        try:
            step = start
            for step in range(start, self.tcfg.total_steps):
                t0 = time.perf_counter()
                host = self.pipeline.batch_at(step)
                batch = {**{k: jax.numpy.asarray(v)
                            for k, v in host.items()}, **self.extra_batch}
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                slow = self.monitor.observe(step, dt)
                rec = {"step": step, "loss": loss, "dt": dt,
                       "straggler": slow,
                       "grad_norm": float(metrics["grad_norm"])}
                self.history.append(rec)
                if step % self.tcfg.log_every == 0:
                    print(f"step {step:6d} loss {loss:.4f} "
                          f"gnorm {rec['grad_norm']:.3f} {dt*1e3:.0f}ms"
                          + (" [straggler]" if slow else ""), flush=True)
                if step and step % self.tcfg.ckpt_every == 0:
                    self._save(step, params, opt_state)
                if self._stop:
                    print(f"preemption signal at step {step}; "
                          "checkpointing and exiting", flush=True)
                    break
            self._save(step + 1, params, opt_state, block=True)
        finally:
            for s, h in zip((signal.SIGTERM, signal.SIGINT), old):
                signal.signal(s, h)
        return params, opt_state
