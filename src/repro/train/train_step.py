"""Jitted train step: loss → grad → (optional compression) → AdamW.

Built once per (model, mesh): `make_train_step` closes over the bundle and
returns a jit'd function with explicit in/out shardings, donating params and
optimizer state.  Microbatching (gradient accumulation) runs as a scan over
microbatch slices inside the same jit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.optim import adamw
from repro.optim.compression import compress_grads, decompress_grads
from repro.sharding import ctx, rules


def make_train_step(bundle, opt_cfg: adamw.AdamWConfig, mesh=None, *,
                    microbatches: int = 1, compress: bool = False,
                    donate: bool = True):
    """Returns train_step(params, opt_state, batch) → (params, state, metrics).

    With compress=True, gradients pass through int8 error-feedback
    quantization before the (pod-crossing) reduction; the residual state
    lives in opt_state["residuals"].
    """

    def loss_fn(params, batch):
        return bundle.loss(params, batch)

    def step(params, opt_state, batch):
        if microbatches > 1:
            def micro(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def to_micro(x):
                # strided split so each microbatch stays shard-aligned
                # across the data axes (row i of microbatch m is global
                # row i*mb + m — every device contributes B_loc/mb rows)
                b = x.shape[0]
                y = x.reshape((b // microbatches, microbatches)
                              + x.shape[1:])
                y = jnp.swapaxes(y, 0, 1)
                return ctx.constrain(
                    y, None, "batch", *([None] * (x.ndim - 1)))

            mbs = jax.tree.map(to_micro, batch)
            from repro.models import flags as _flags
            (g, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros(())), mbs,
                unroll=True if _flags.scan_unroll() else 1)
            g = jax.tree.map(lambda x: x / microbatches, g)
            loss = loss / microbatches
        else:
            loss, g = jax.value_and_grad(loss_fn)(params, batch)

        if compress:
            comp, res = compress_grads(g, opt_state["residuals"])
            g = decompress_grads(comp)
            opt_state = {**opt_state, "residuals": res}

        inner = {k: v for k, v in opt_state.items() if k != "residuals"}
        params, inner, metrics = adamw.apply_updates(params, g, inner,
                                                     opt_cfg)
        if compress:
            inner["residuals"] = opt_state["residuals"]
        metrics["loss"] = loss
        return params, inner, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    def shard_fn(params, opt_state, batch_specs):
        pspecs = rules.param_specs(params)
        ospecs = rules.param_specs(opt_state)  # mirrors params (+ scalars)
        return pspecs, ospecs

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def init_opt_state(params, *, compress: bool = False, dtype=None):
    import jax.numpy as jnp
    st = adamw.init_state(params, dtype or jnp.float32)
    if compress:
        from repro.optim.compression import init_residuals
        st["residuals"] = init_residuals(params)
    return st
