"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * table1_*            — executable feature matrix (capability probes)
  * local_fft_*         — local line-DFT backends (measured, CPU)
  * pw_staged/padded_*  — staged-pad vs full-pad plane-wave (measured, CPU)
  * fig9_*              — strong-scaling model for the paper's five Fig. 9
                          variants on TPU-v5e constants, fed by FftPlan's
                          comm/flop model at each processor count
  * train/decode_step   — reduced-config step microbenches (measured, CPU)

``derived`` column: modeled ms for fig9 rows, speedup/ratios elsewhere.
The SCF scenarios (``scf`` on a 1D fft grid, ``scf-2d`` pipelined on a
batch×fft 2D grid, ``scf-stacked`` with the batched stacked band-update
engine on the same 2D grid, ``scf-jit`` adding the fused jit-compiled SCF
step, ``scf-3d`` on a batch×fft×fft *pencil* grid with segmented ragged
stacking — each recording its grid shape, padding fraction, segment
count, band-update route and per-iteration wall time) additionally write
machine-readable schema-5 ``BENCH_scf.json`` (transforms/s, iterations
to convergence, plan-cache hit rate, per-segment realized padding, plus
a per-scenario ``metrics`` delta from the ``repro.obs`` registry so
regressions attribute to a phase) so the perf trajectory can be tracked
across commits; CI's bench-trajectory job uploads it and gates
regressions against ``benchmarks/baseline.json`` via
``benchmarks/compare.py`` (schema-3/4 baselines still load).  The
``band_update`` field rides the record so the gate catches a silent
fallback from the stacked engine to the per-k path; the stacked/jit/3d
scenarios additionally hard-fail here if the route they exist to measure
did not engage.  The JSON is written atomically (temp file + rename) so
an interrupted run can't leave a truncated artifact.

``--scenarios gate`` resolves the scenario list from the committed
baseline (``--baseline``), so the CI gate jobs and the baseline-drift
automation share one source of truth for what is gated — adding a
scenario to the baseline is what starts gating it, with no workflow
edits.  ``--merge`` folds this run's records into an existing
``--json-out`` instead of replacing it: CI's bench-trajectory job runs
the 4-device scenarios first, then merges the 8-device ``scf-3d`` record
into the same BENCH_scf.json before a single gate invocation (the gate
fails on baseline scenarios missing from the current run, so the merged
artifact is what gets compared).

Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--json-out PATH]
         [--scenarios scf,scf-2d,scf-stacked,scf-jit,scf-pallas,scf-3d
          | gate]
         [--merge] [--baseline PATH] [--trace-out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

#: selectable benchmark scenarios (--scenarios comma list, default all;
#: the literal ``gate`` resolves to whatever the baseline gates)
SCENARIOS = ("table1", "plan_cache", "local_fft", "planewave", "fig9",
             "serve-transform",
             "scf", "scf-2d", "scf-stacked", "scf-jit", "scf-3d",
             "scf-pallas", "steps")


def _timeit(fn, *args, warmup=2, iters=5):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6          # µs


def bench_table1(rows):
    """Paper Table 1 — capabilities, as executable probes."""
    import jax
    import jax.numpy as jnp
    from repro.core import (ProcGrid, SphereDomain, Domain, fftb,
                            make_planewave_pair)
    g1 = ProcGrid.create([1])
    t0 = time.perf_counter()
    dom = Domain((0, 0, 0), (15, 15, 15))
    fx = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g1)
    # block before stopping the clock — jax dispatch is asynchronous, and
    # an un-drained call would time only the dispatch (see
    # repro.obs.trace.timed_call for the canonical pattern)
    jax.block_until_ready(fx(jnp.ones((16, 16, 16), jnp.complex64)))
    rows.append(("table1_ctoc_cuboid", (time.perf_counter() - t0) * 1e6, 1))
    t0 = time.perf_counter()
    sph = SphereDomain.from_diameter(8)
    inv, fwd = make_planewave_pair(g1, 16, sph, 4)
    jax.block_until_ready(inv(jnp.ones((4, 8, 8, 8), jnp.complex64)))
    rows.append(("table1_sphere_batched", (time.perf_counter() - t0) * 1e6,
                 1))
    for nd in (1, 2, 3):
        g = ProcGrid.create_abstract([1] * nd)
        rows.append((f"table1_grid_{nd}d", 0.0, g.ndim))


def bench_plan_cache(rows):
    """Plan build cost vs cached lookup — the serving-path win."""
    from repro.core import Domain, ProcGrid, fftb, PlanCache
    g = ProcGrid.create_abstract([8])
    dom = Domain((0, 0, 0), (63, 63, 63))
    cache = PlanCache()
    spec = "b x{0} y z -> b X Y Z{0}"
    b = Domain((0,), (255,))
    t0 = time.perf_counter()
    fftb.plan_for(spec, domains=(b, dom), grid=g, cache=cache)
    build_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    iters = 100
    for _ in range(iters):
        fftb.plan_for(spec, domains=(b, dom), grid=g, cache=cache)
    hit_us = (time.perf_counter() - t0) * 1e6 / iters
    rows.append(("plan_build_cold", build_us, 1))
    rows.append(("plan_cache_hit", hit_us,
                 round(build_us / max(hit_us, 1e-3), 1)))   # speedup ×


def bench_local_fft(rows, quick=False):
    import jax
    import jax.numpy as jnp
    from repro.core.local_fft import local_dft
    rng = np.random.default_rng(0)
    sizes = [64, 128] if quick else [64, 128, 256]
    batch = 512
    for n in sizes:
        x = jnp.asarray((rng.standard_normal((batch, n))
                         + 1j * rng.standard_normal((batch, n))
                         ).astype(np.complex64))
        for backend in ("jnp", "matmul"):
            f = jax.jit(lambda a, b=backend: local_dft(a, -1, backend=b))
            us = _timeit(f, x)
            # derived: GFLOP/s using the 8·n² matmul-form flop count
            gflops = 8 * n * n * batch / (us * 1e-6) / 1e9
            rows.append((f"local_fft_{backend}_n{n}", us, round(gflops, 2)))
        # rectangular (pad-fused) form — the plane-wave stage shape
        f = jax.jit(lambda a, m=2 * n: local_dft(a, -1, m, backend="matmul"))
        us = _timeit(f, x)
        rows.append((f"local_fft_rect_n{n}to{2*n}", us,
                     round(8 * 2 * n * n * batch / (us * 1e-6) / 1e9, 2)))


def bench_planewave(rows, quick=False):
    """§2.2/Fig. 2-3: staged-pad vs pad-everything-first, measured."""
    import jax
    import jax.numpy as jnp
    from repro.core import (Domain, DistTensor, FftPlan, ProcGrid,
                            make_planewave_pair, sphere_for_cutoff)
    g = ProcGrid.create([1])
    n = 32 if quick else 64
    sph = sphere_for_cutoff(n)
    d = sph.extents[0]
    nb = 4
    inv, _ = make_planewave_pair(g, n, sph, nb)
    rng = np.random.default_rng(1)
    cube = jnp.asarray((rng.standard_normal((nb, d, d, d))
                        + 1j * rng.standard_normal((nb, d, d, d))
                        ).astype(np.complex64))
    us_staged = _timeit(inv.plan._sharded_fn, cube)
    b = Domain((0,), (nb - 1,))
    cdom = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
    ti = DistTensor.create((b, cdom), "b x{0} y z", g)
    to = DistTensor.create((b, cdom), "B X Y Z{0}", g)
    padded = FftPlan(ti, to, [("x", "X"), ("y", "Y"), ("z", "Z")],
                     inverse=True)
    full = jnp.zeros((nb, n, n, n), jnp.complex64)
    full = full.at[:, :d, :d, :d].set(cube)
    us_padded = _timeit(padded._sharded_fn, full)
    rows.append((f"pw_staged_n{n}", us_staged,
                 round(inv.flop_count() / 1e6, 1)))
    rows.append((f"pw_padded_n{n}", us_padded,
                 round(padded.flop_count() / 1e6, 1)))
    rows.append((f"pw_speedup_n{n}", 0.0, round(us_padded / us_staged, 2)))
    rows.append((f"pw_data_ratio_n{n}", 0.0,
                 round(n ** 3 / sph.npacked, 2)))   # paper's ~16× claim


# ---------------------------------------------------------------- Fig. 9
_PEAK = 197e12          # bf16 FLOP/s per chip (TPU v5e)
_LINK = 50e9            # B/s per ICI link
_LAT = 5e-6             # per-collective latency (s)
_EFF = 0.35             # sustained fraction of peak for line DFTs
_HALF_BW = 65536        # message size reaching half link bandwidth (B)


def _fig9_time(plan, nb_msgs_scale=1):
    """LogGP-style: per-peer message size below ~64 KiB degrades effective
    bandwidth — exactly why the paper's unbatched variants collapse beyond
    64 GPUs while batched ones keep scaling (its central Fig. 9 claim)."""
    comp = plan.flop_count() / plan.grid.nprocs / (_PEAK * _EFF)
    comm = 0.0
    for st in plan.comm_stats():
        msg = st["bytes_per_device"] / max(st["procs"] - 1, 1)
        bw = _LINK * msg / (msg + _HALF_BW)
        comm += st["bytes_per_device"] / bw + _LAT * nb_msgs_scale
    return (comp + comm) * 1e3                                # ms


def bench_fig9(rows):
    """Paper Fig. 9: 256³ FFT, batch 256, sphere d=128 — five variants
    across processor counts, priced by the plan's comm/flop model."""
    from repro.core import (Domain, DistTensor, FftPlan, ProcGrid,
                            SphereDomain, make_planewave_pair)
    n, nb, d = 256, 256, 128
    for P in (4, 8, 16, 32, 64, 128, 256, 512, 1024):
        b = Domain((0,), (nb - 1,))
        cube = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
        sph = SphereDomain.from_diameter(d)

        # --- 1D grid, batched (dark blue) ---
        if P <= n:
            g = ProcGrid.create_abstract([P])
            ti = DistTensor.create((b, cube), "b x{0} y z", g)
            to = DistTensor.create((b, cube), "B X Y Z{0}", g)
            plan = FftPlan(ti, to, [("x", "X"), ("y", "Y"), ("z", "Z")])
            rows.append((f"fig9_1d_batched_p{P}", 0.0,
                         round(_fig9_time(plan), 3)))
            # --- 1D grid, unbatched (light blue): 256 separate small
            # transforms → per-message latency dominates at scale
            ti1 = DistTensor.create(cube, "x{0} y z", g)
            to1 = DistTensor.create(cube, "X Y Z{0}", g)
            p1 = FftPlan(ti1, to1, [("x", "X"), ("y", "Y"), ("z", "Z")])
            t1 = _fig9_time(p1) * nb + _LAT * nb * 1e3
            rows.append((f"fig9_1d_unbatched_p{P}", 0.0, round(t1, 3)))

        # --- 2D grid, batched (dark orange) ---
        if P >= 4:
            good = 1
            px = 1
            while px * px <= P:
                if P % px == 0 and (P // px) <= n and px <= n:
                    good = px
                px += 1
            g2 = ProcGrid.create_abstract([good, P // good])
            ti2 = DistTensor.create((b, cube), "b x{0} y{1} z", g2)
            to2 = DistTensor.create((b, cube), "B X Y{0} Z{1}", g2)
            plan2 = FftPlan(ti2, to2, [("x", "X"), ("y", "Y"), ("z", "Z")])
            rows.append((f"fig9_2d_batched_p{P}", 0.0,
                         round(_fig9_time(plan2), 3)))

        # --- plane-wave staged (red) ---
        if P <= d:
            gpw = ProcGrid.create_abstract([P])
            inv, _ = make_planewave_pair(gpw, n, sph, nb)
            rows.append((f"fig9_planewave_p{P}", 0.0,
                         round(_fig9_time(inv.plan), 3)))
        else:                       # parallelize batch beyond the dims
            fft_p = d
            bat_p = P // d
            if nb % bat_p == 0:
                gpw = ProcGrid.create_abstract([bat_p, fft_p])
                inv, _ = make_planewave_pair(gpw, n, sph, nb,
                                             batch_axes=(0,),
                                             fft_axes=(1,))
                rows.append((f"fig9_planewave_p{P}", 0.0,
                             round(_fig9_time(inv.plan), 3)))


def bench_scf(rows, quick=False, grid_shape=None, tag="scf",
              stack_k=None, jit_step=False, segment_padding=None,
              backend=None):
    """repro.dft SCF scenario — the paper's end-to-end workload.

    Two k-points (two distinct sphere plans) + the full-cube Hartree pair,
    mixing-driven SCF, on a 1D fft-only grid (``tag='scf'``), a 2D
    batch×fft grid (``tag='scf-2d'``, grid_shape e.g. (2, 2) — bands shard
    the batch axis), or a 3D batch×fft×fft pencil grid (``tag='scf-3d'``,
    grid_shape e.g. (2, 2, 2) — two decomposed fft axes).  ``stack_k``
    pins the H-sweep route: False keeps the pipelined per-k dispatch (so
    ``scf-2d`` stays comparable across commits), True rides the ragged
    k-stacked batch and the batched band-update engine (``scf-stacked``);
    ``jit_step`` additionally fuses each outer iteration into one
    jit-compiled step (``scf-jit``); ``segment_padding`` caps per-segment
    realized padding so the stacked batch splits into segments instead of
    padding every k to the global max (``scf-3d``); ``backend`` pins the
    line-DFT backend — ``"pallas"`` routes the Hamiltonian hot path
    through the fused sphere-pack kernels (``scf-pallas``), and the
    *resolved* backend lands in the scenario record so the gate catches a
    silent downgrade.  Returns the
    machine-readable schema-5 record merged into BENCH_scf.json;
    ``grid_shape`` is what the trajectory gate keys scenarios by,
    ``band_update``/``segments`` let it catch a silent fallback to the
    per-k path or a changed segmentation, and ``seconds_per_iteration``
    tracks per-sweep wall time next to ``transforms_per_s``.
    """
    import jax
    from repro.core import ProcGrid, global_plan_cache
    from repro.dft import SCFConfig, run_scf
    from repro.sharding.grids import DFT_AXES_1D, DFT_AXES_2D, DFT_AXES_3D
    if grid_shape is None:
        grid_shape = (jax.device_count(),)
    grid_shape = tuple(grid_shape)
    names = {1: DFT_AXES_1D, 2: DFT_AXES_2D, 3: DFT_AXES_3D}[len(grid_shape)]
    grid = ProcGrid.create(list(grid_shape), list(names))
    cfg = SCFConfig(n=16, nbands=4, kpts=((0, 0, 0), (0.5, 0.5, 0.5)),
                    max_iter=20 if quick else 50,
                    e_tol=1e-4 if quick else 1e-5,
                    r_tol=1e-3 if quick else 1e-4,
                    stack_k=stack_k, jit_step=jit_step,
                    segment_padding=segment_padding, backend=backend)
    global_plan_cache().clear()
    res = run_scf(cfg, grid=grid)
    c = res.cache_stats
    lookups = c["hits"] + c["misses"]
    hit_rate = c["hits"] / max(lookups, 1)
    label = tag.replace("-", "_")
    rows.append((f"{label}_outer_iteration",
                 res.seconds_per_iteration * 1e6,
                 res.iterations))
    rows.append((f"{label}_transforms_per_s", 0.0,
                 round(res.transforms_per_s, 1)))
    rows.append((f"{label}_cache_hit_rate", 0.0, round(hit_rate, 4)))
    return {
        "scenario": {
            "n": cfg.n, "nbands": cfg.nbands, "kpts": list(cfg.kpts),
            "max_iter": cfg.max_iter, "e_tol": cfg.e_tol,
            "devices": jax.device_count(), "quick": bool(quick),
            "jit_step": bool(cfg.jit_step),
            "segment_padding": segment_padding,
            "backend": res.backend,
        },
        "grid_shape": list(grid_shape),
        "grid_rank": len(grid_shape),
        "pipeline": bool(cfg.pipeline),
        "stacked": bool(res.stacked),
        "band_update": res.band_update,
        "jitted": bool(res.jitted),
        "padding_fraction": round(res.padding_fraction, 4),
        "segments": res.segments,
        "segment_padding_fractions": [
            round(f, 4) for f in res.segment_padding_fractions],
        "converged": bool(res.converged),
        "scf_iterations": res.iterations,
        "total_energy": res.energy,
        "transforms": res.transforms,
        "transforms_unit": "per-band 3D transforms (plans batch bands)",
        "transforms_per_s": round(res.transforms_per_s, 2),
        "seconds": round(res.seconds, 3),
        "seconds_per_iteration": round(res.seconds_per_iteration, 4),
        "plan_cache": {"hits": c["hits"], "misses": c["misses"],
                       "hit_rate": round(hit_rate, 4)},
    }


def bench_serve_transform(rows, quick=False):
    """Transform-service scenario: a mixed-tenant trace, coalesced.

    Three tenants replay a fixed trace over three sphere shapes (two
    cutoffs × two k-shifts) in waves of 8 against one ``TransformService``
    on an fft-only grid sized to the device count.  Plans warm on a
    throwaway replay first; the measured window then records sustained
    requests/s, per-request latency percentiles, realized padding and
    plan-cache behaviour — the numbers the schema-4 gate checks
    (``requests_per_s`` higher-is-better, ``latency_p99_ms``
    lower-is-better, next to the universal ``transforms_per_s``).
    ``converged`` here means the run was healthy: every request resolved,
    no deadline/dispatch errors.
    """
    import jax
    from repro.core import ProcGrid, global_plan_cache, kpoint_sphere
    from repro.serve import TransformService

    n, d = 16, 8
    padding_budget, max_rows = 0.5, 8
    n_requests = 24 if quick else 96
    grid_shape = (jax.device_count(),)
    grid = ProcGrid.create(list(grid_shape), ["dft_f"])
    global_plan_cache().clear()
    svc = TransformService(grid, n, padding_budget=padding_budget,
                           max_rows=max_rows, warm_async=False)

    # the small-cutoff tenant needs a diameter the fft axis can shard
    d_small = next(c for c in (6, 4, 8) if c % jax.device_count() == 0)
    spheres = [kpoint_sphere(d), kpoint_sphere(d, (0.5, 0.5, 0.5)),
               kpoint_sphere(d_small)]
    rng = np.random.default_rng(0)
    veff = rng.standard_normal((n,) * 3).astype(np.float32)

    def request(i):
        tenant = ("alpha", "beta", "gamma")[i % 3]
        sphere = spheres[i % 3]
        nbands = (2, 2, 1)[i % 3]
        c = (rng.standard_normal((nbands, sphere.npacked))
             + 1j * rng.standard_normal((nbands, sphere.npacked))
             ).astype(np.complex64)
        return tenant, c, sphere, (veff if i % 2 == 0 else None)

    trace = [request(i) for i in range(n_requests)]

    def replay():
        for i in range(0, len(trace), 8):
            for tenant, c, sphere, v in trace[i:i + 8]:
                svc.submit(tenant, c, sphere, v_eff=v)
            svc.run_until_idle()

    replay()                      # warm: plans built, executors traced
    svc.metrics.reset()
    replay()                      # measured window
    m = svc.metrics.summary()

    healthy = m["requests"] == n_requests and not m["errors"]
    rows.append(("serve_requests_per_s", 0.0, m["requests_per_s"]))
    rows.append(("serve_latency_p99_ms", 0.0, m["latency_p99_ms"]))
    rows.append(("serve_padding_fraction", 0.0,
                 m["padding_fraction_mean"]))
    return {
        "scenario": {
            "n": n, "d": d, "d_small": d_small,
            "tenants": 3, "requests": n_requests,
            "padding_budget": padding_budget, "max_rows": max_rows,
            "devices": jax.device_count(), "quick": bool(quick),
        },
        "grid_shape": list(grid_shape),
        "pipeline": False,
        "stacked": True,
        "band_update": "coalesced",
        "converged": healthy,
        "requests": m["requests"],
        "requests_per_s": m["requests_per_s"],
        "transforms": m["transforms"],
        "transforms_unit": "per-band sphere<->cube round trips",
        "transforms_per_s": m["transforms_per_s"],
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "dispatches": m["dispatches"],
        "coalesced_dispatches": m["coalesced_dispatches"],
        "padding_fraction": m["padding_fraction_mean"],
        "plan_cache": m["plan_cache"],
        "per_tenant": m["per_tenant"],
    }


def bench_steps(rows):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.models.model_zoo import build
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_opt_state, make_train_step
    cfg = get_config("tinyllama-1.1b").reduced()
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)),
                                   jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    step = make_train_step(bundle, AdamWConfig(), donate=False)
    opt = init_opt_state(params)
    us = _timeit(lambda: step(params, opt, batch)[2]["loss"])
    tokens = 4 * 64
    rows.append(("train_step_reduced", us,
                 round(tokens / (us * 1e-6), 0)))       # tokens/s
    cache = bundle.init_cache(4, 128, jnp.float32)
    lengths = jnp.full((4,), 64, jnp.int32)
    dec = jax.jit(bundle.decode)
    tok = jnp.ones((4, 1), jnp.int32)
    us = _timeit(lambda: dec(params, tok, cache, lengths)[0])
    rows.append(("decode_step_reduced", us, round(4 / (us * 1e-6), 0)))


def _metrics_window(fn):
    """Run a scenario, embedding the obs-registry delta in its record.

    ``record["metrics"]`` is ``diff_snapshot`` over the window the
    scenario ran in — counter deltas (fftb executions, cache builds,
    per-k linalg calls) that let ``compare.py`` attribute a regression
    to a phase rather than just flag the end-to-end number.
    """
    from repro.obs.metrics import diff_snapshot, global_metrics
    before = global_metrics().snapshot()
    record = fn()
    record["metrics"] = diff_snapshot(before, global_metrics().snapshot())
    return record


def atomic_json_dump(record, path: str) -> None:
    """Write JSON via a temp file + atomic rename.

    An interrupted benchmark run (CI timeout, OOM-kill) must not leave a
    truncated ``BENCH_scf.json`` behind — the artifact either has the old
    complete contents or the new complete contents, never half of one.
    """
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".bench-", suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


#: the fixed SCF scenario shape (bench_scf's SCFConfig) — the 2D split
#: must divide these or PlaneWaveBasis rejects the grid
SCF_NBANDS = 4
SCF_DIAMETER = 8
SCF_NK = 2


def scf_2d_grid_shape(ndevices: int) -> tuple[int, int] | None:
    """(batch, fft) split for the scf-2d scenario, None when infeasible.

    Delegates to ``repro.sharding.grids.choose_dft_grid_shape`` — the same
    policy ``--grid auto`` gives users — so the benchmark measures a grid
    the product code would actually pick.  None (skip the scenario, don't
    abort the run) when the chooser stays 1D: fewer than 4 devices, or no
    split dividing the scenario's band count / sphere diameter.
    """
    from repro.sharding.grids import choose_dft_grid_shape
    if ndevices < 4:
        return None
    shape = choose_dft_grid_shape(ndevices, nbands=SCF_NBANDS,
                                  diameter=SCF_DIAMETER, nk=SCF_NK)
    return shape if len(shape) == 2 else None


def scf_stacked_grid_shape(ndevices: int) -> tuple[int, int] | None:
    """The scf-2d split, kept only when the k-stacked batch shards evenly.

    ``basis.stacks_k`` needs the batch factor to carry whole k-points
    (``nk | pb``; the other stacks_k condition, pb | nk·nbands, already
    follows from the chooser's pb | nbands requirement) — otherwise the
    scenario would silently measure the pipelined fallback, so skip it.
    """
    shape = scf_2d_grid_shape(ndevices)
    if shape is None:
        return None
    if shape[0] % SCF_NK:
        return None
    return shape


#: scf-3d's per-segment padding budget.  The scenario's two d=8 spheres
#: pack 280 and 254 coefficients — stacking both in one segment realizes
#: ~4.6% padding, so a 2% budget deterministically splits them into two
#: per-k segments (each realizing 0%), exercising the segmented route
#: end to end.  With the pencil grid's batch factor pb=2, singleton
#: segments still stack (pb % 1 == 0 and 1·nbands % pb == 0).
SCF_SEGMENT_PADDING = 0.02


def scf_3d_grid_shape(ndevices: int) -> tuple[int, int, int] | None:
    """(batch, fft, fft) pencil split for scf-3d, None when infeasible.

    Same chooser as the other grid pickers; the pencil tier engages from
    8 devices for the scenario shape (nbands=4, d=8 → (2, 2, 2)).  None
    when the chooser stays 1D/2D — fewer than 8 devices, or no per-axis
    fft split within the chooser's max-fft-fraction guard.
    """
    from repro.sharding.grids import choose_dft_grid_shape
    if ndevices < 8:
        return None
    shape = choose_dft_grid_shape(ndevices, nbands=SCF_NBANDS,
                                  diameter=SCF_DIAMETER, nk=SCF_NK)
    return shape if len(shape) == 3 else None


def require_stacked_route(record: dict, tag: str) -> dict:
    """Hard-fail when a stacked-route scenario fell back to per-k.

    ``scf-stacked``/``scf-jit`` exist to measure the batched band-update
    engine; a record that quietly took the per-k path would be compared
    against stacked baselines and read as a perf cliff (or mask one).
    The gate also rejects such records via the ``band_update`` config
    key, but the run itself should refuse to emit them.
    """
    if record.get("band_update") != "stacked":
        raise SystemExit(
            f"{tag}: band-update route was {record.get('band_update')!r}, "
            "expected 'stacked' — the scenario's grid no longer satisfies "
            "basis.stacks_k; fix the grid choice rather than benchmarking "
            "the fallback under a stacked label")
    return record


def require_backend(record: dict, tag: str, backend: str) -> dict:
    """Hard-fail when a backend-pinned scenario silently ran another route.

    ``scf-pallas`` exists to measure the fused sphere-pack kernels; its
    record must carry the requested backend *and*, for "pallas", show
    fused kernel dispatches in the scenario's metrics window — a record
    whose H sweeps quietly composed unpack/plan/pack would be compared
    against fused baselines and mask (or fake) a perf cliff.
    """
    got = record.get("scenario", {}).get("backend")
    if got != backend:
        raise SystemExit(
            f"{tag}: resolved backend was {got!r}, expected {backend!r} — "
            "refusing to emit a mislabeled record")
    if backend == "pallas":
        fused = record.get("metrics", {}).get("sphere_pack", {})
        if not (fused.get("unpack_dft", 0) > 0
                and fused.get("dft_pack", 0) > 0):
            raise SystemExit(
                f"{tag}: no fused sphere-pack dispatches in the metrics "
                f"window ({fused}) — the H sweeps fell back to the "
                "composed unpack/plan/pack route; fix the fusion guards "
                "rather than benchmarking the fallback under a pallas "
                "label")
    return record


def write_scenario_records(scf_records: dict, json_out: str,
                           merge: bool = False) -> dict:
    """Atomically write the schema-5 artifact; with ``merge``, fold the
    new records into whatever ``json_out`` already holds.

    The merge path is how CI's 8-device scf-3d step joins the 4-device
    scenarios in one BENCH_scf.json: the gate fails on baseline
    scenarios missing from the artifact it is handed, so both runs must
    land in the same file before the single compare invocation.  Same
    scenario name twice → the later run wins (a deliberate re-measure).
    Returns the merged scenario dict that was written.
    """
    merged = dict(scf_records)
    if merge and os.path.exists(json_out):
        with open(json_out) as f:
            prev = json.load(f)
        merged = dict(prev.get("scenarios", {}))
        merged.update(scf_records)
    atomic_json_dump({"schema": 5, "scenarios": merged}, json_out)
    return merged


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json-out", default="BENCH_scf.json",
                    help="path for the machine-readable SCF record")
    ap.add_argument("--merge", action="store_true",
                    help="fold this run's scenario records into an "
                         "existing --json-out instead of replacing it "
                         "(CI's 8-device scf-3d step merges into the "
                         "4-device artifact before the single gate call)")
    ap.add_argument("--scenarios", default="all",
                    help="comma list from %s, or the literal 'gate' to "
                         "run exactly the scenarios the baseline gates"
                         % ",".join(SCENARIOS))
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__),
                                         "baseline.json"),
                    help="baseline JSON that '--scenarios gate' resolves "
                         "the scenario list from (default: the committed "
                         "benchmarks/baseline.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(per-stage spans with sync at span exit — "
                         "perturbs timings, never gate a traced run)")
    args = ap.parse_args(argv)
    if args.trace_out:
        from repro.obs.trace import get_tracer
        get_tracer().enable(sync=True, per_stage=True)
    if args.scenarios == "all":
        wanted = set(SCENARIOS)
    elif args.scenarios == "gate":
        # single source of truth for the gated scenario list: whatever
        # the committed baseline knows is what CI runs — adding a
        # scenario to the baseline starts gating it, no workflow edits
        try:
            with open(args.baseline) as f:
                base = json.load(f)["scenarios"]
        except (OSError, KeyError, json.JSONDecodeError) as e:
            ap.error(f"--scenarios gate: cannot resolve scenario list "
                     f"from {args.baseline}: {e}")
        wanted = set(base) & set(SCENARIOS)
        stale = sorted(set(base) - set(SCENARIOS))
        if stale:
            print(f"# WARNING: baseline gates unknown scenario(s) "
                  f"{stale} — this harness cannot run them")
        if not wanted:
            ap.error(f"--scenarios gate: {args.baseline} gates no "
                     "scenario this harness knows")
        print(f"# gate scenarios from {args.baseline}: "
              f"{', '.join(sorted(wanted))}")
    else:
        wanted = {s.strip() for s in args.scenarios.split(",") if s.strip()}
        bad = wanted - set(SCENARIOS)
        if bad:
            ap.error(f"unknown scenarios {sorted(bad)}; "
                     f"choose from {SCENARIOS}")
    rows: list[tuple[str, float, object]] = []
    scf_records: dict[str, dict] = {}
    if "table1" in wanted:
        bench_table1(rows)
    if "plan_cache" in wanted:
        bench_plan_cache(rows)
    if "local_fft" in wanted:
        bench_local_fft(rows, args.quick)
    if "planewave" in wanted:
        bench_planewave(rows, args.quick)
    if "fig9" in wanted:
        bench_fig9(rows)
    if "serve-transform" in wanted:
        scf_records["serve-transform"] = _metrics_window(
            lambda: bench_serve_transform(rows, args.quick))
    if "scf" in wanted:
        scf_records["scf"] = _metrics_window(
            lambda: bench_scf(rows, args.quick, tag="scf"))
    if "scf-2d" in wanted:
        import jax
        shape = scf_2d_grid_shape(jax.device_count())
        if shape is None:
            print(f"# scf-2d skipped: no feasible batch×fft split for "
                  f"{jax.device_count()} device(s) — needs >= 4 with the "
                  f"batch factor dividing nbands={SCF_NBANDS} and the fft "
                  f"factor dividing d={SCF_DIAMETER} "
                  "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        else:
            # stack_k pinned off: scf-2d tracks the pipelined per-k path,
            # scf-stacked below tracks the ragged k-stacked H apply
            scf_records["scf-2d"] = _metrics_window(
                lambda: bench_scf(rows, args.quick, grid_shape=shape,
                                  tag="scf-2d", stack_k=False))
    if "scf-stacked" in wanted:
        import jax
        shape = scf_stacked_grid_shape(jax.device_count())
        if shape is None:
            print(f"# scf-stacked skipped: no batch×fft split for "
                  f"{jax.device_count()} device(s) whose batch factor "
                  f"carries the nk·nbands = {SCF_NK}·{SCF_NBANDS} stacked "
                  "batch (XLA_FLAGS=--xla_force_host_platform_device_"
                  "count=4)")
        else:
            scf_records["scf-stacked"] = require_stacked_route(
                _metrics_window(
                    lambda: bench_scf(rows, args.quick, grid_shape=shape,
                                      tag="scf-stacked", stack_k=True)),
                "scf-stacked")
    if "scf-jit" in wanted:
        import jax
        shape = scf_stacked_grid_shape(jax.device_count())
        if shape is None:
            print(f"# scf-jit skipped: needs the scf-stacked grid (a "
                  f"batch×fft split whose batch factor carries the "
                  f"nk·nbands = {SCF_NK}·{SCF_NBANDS} stacked batch); "
                  f"{jax.device_count()} device(s) have none "
                  "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        else:
            scf_records["scf-jit"] = require_stacked_route(
                _metrics_window(
                    lambda: bench_scf(rows, args.quick, grid_shape=shape,
                                      tag="scf-jit", stack_k=True,
                                      jit_step=True)),
                "scf-jit")
    if "scf-pallas" in wanted:
        import jax
        # the probe must exist before the metrics window opens so the
        # record's delta starts from this scenario, not process start
        import repro.kernels.sphere_pack  # noqa: F401
        shape = scf_stacked_grid_shape(jax.device_count())
        if shape is None:
            print(f"# scf-pallas skipped: needs the scf-stacked grid (a "
                  f"batch×fft split whose batch factor carries the "
                  f"nk·nbands = {SCF_NK}·{SCF_NBANDS} stacked batch); "
                  f"{jax.device_count()} device(s) have none "
                  "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        else:
            scf_records["scf-pallas"] = require_backend(
                require_stacked_route(
                    _metrics_window(
                        lambda: bench_scf(rows, args.quick,
                                          grid_shape=shape,
                                          tag="scf-pallas", stack_k=True,
                                          backend="pallas")),
                    "scf-pallas"),
                "scf-pallas", "pallas")
    if "scf-3d" in wanted:
        import jax
        shape = scf_3d_grid_shape(jax.device_count())
        if shape is None:
            print(f"# scf-3d skipped: no batch×fft×fft pencil split for "
                  f"{jax.device_count()} device(s) — needs >= 8 with the "
                  f"batch factor dividing nbands={SCF_NBANDS} and each "
                  f"fft factor within the d={SCF_DIAMETER} sphere's "
                  "per-axis guard (XLA_FLAGS=--xla_force_host_platform_"
                  "device_count=8)")
        else:
            scf_records["scf-3d"] = require_stacked_route(
                _metrics_window(
                    lambda: bench_scf(rows, args.quick, grid_shape=shape,
                                      tag="scf-3d", stack_k=True,
                                      segment_padding=SCF_SEGMENT_PADDING)),
                "scf-3d")
    if "steps" in wanted:
        # --quick drops steps from the default "all" sweep, but an
        # explicitly requested scenario always runs
        if args.scenarios != "all":
            bench_steps(rows)
        elif not args.quick:
            bench_steps(rows)
        else:
            print("# steps skipped under --quick (request it explicitly "
                  "with --scenarios steps to run anyway)")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if scf_records:
        merged = write_scenario_records(scf_records, args.json_out,
                                        merge=args.merge)
        print(f"# wrote {args.json_out} "
              f"(scenarios: {', '.join(merged)})")
    if args.trace_out:
        from repro.obs.trace import get_tracer
        tr = get_tracer()
        tr.disable()
        tr.export_chrome(args.trace_out)
        print(f"# wrote {args.trace_out} ({len(tr.events())} trace "
              "events) — traced timings are not gate-comparable")


if __name__ == '__main__':
    main()
