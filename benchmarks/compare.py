"""Perf-trajectory gate: compare a BENCH_scf.json against the baseline.

CI's bench-trajectory job runs the SCF scenarios (1D and 2D grids), uploads
the fresh ``BENCH_scf.json`` as an artifact, then runs this module against
the committed ``benchmarks/baseline.json``:

    PYTHONPATH=src python -m benchmarks.compare BENCH_scf.json \\
        benchmarks/baseline.json --tolerance 0.20

Exit 1 when any scenario's ``transforms_per_s`` regressed more than the
tolerance, when a baseline scenario disappeared from the current run, or
when a scenario stopped converging — a silently dropped scenario must not
read as a pass.  Scenarios whose baseline records serving metrics (the
``serve-transform`` mixed-tenant trace) additionally gate
``requests_per_s`` (same tolerance, higher-is-better) and
``latency_p99_ms`` (twice the tolerance, lower-is-better — p99 on shared
runners is noisier than sustained throughput); SCF scenarios carry
neither and are unaffected.  Scenario configs (devices, quick flag, grid shape) are
checked too, as are the *route* fields ``pipeline``/``stacked``/
``band_update``: a scenario that silently fell back from the stacked
band-update engine to the per-k path is a different configuration, not a
perf data point — the gate catches exactly that fallback.  Schema-5
records additionally carry ``segments`` (the segmented ragged-stacking
count); it gates as a config key when the baseline knows it, so a
changed segmentation reads as a config mismatch, while schema-4
baselines compare exactly as before.  Comparing
numbers measured under different configurations is an error, not a pass.
The other direction is *not* an error: a scenario present in the current
run but absent from the baseline (a freshly added benchmark, e.g.
``scf-jit`` before its first baseline refresh) is skipped with a warning
and does not fail the gate — known scenarios still gate normally.
Refresh the baseline to start gating it.

Refresh the baseline after an intentional perf change with::

    PYTHONPATH=src python -m benchmarks.compare BENCH_scf.json \\
        benchmarks/baseline.json --update-baseline

**Drift check** (the scheduled baseline-refresh automation): with
``--check-drift FRAC`` the gate runs as usual, and *additionally* reports
scenarios whose throughput moved more than ``FRAC`` in **either**
direction while still passing the gate.  Scenarios the baseline does not
know yet count as a refresh signal too — otherwise a freshly added
benchmark would stay ungated forever (the gate only warns about it, and
pure drift only looks at scenarios both records share).  Exit codes make
the three outcomes scriptable:

    0 — gate passed, no drift beyond FRAC, no unknown scenarios
    1 — gate failed (regression/config mismatch; drift not evaluated)
    2 — gate passed but the baseline is stale (drift beyond FRAC and/or
        scenarios missing from it): refresh the baseline

The ``baseline-drift`` scheduled workflow uses exit 2 to open a PR that
refreshes ``benchmarks/baseline.json`` via ``--update-baseline``.
"""
from __future__ import annotations

import argparse
import json
import sys

#: record keys that must match between baseline and current run —
#: scenario config plus the route fields (a switched band-update route or
#: pipeline flag measures a different configuration, not a perf delta)
CONFIG_KEYS = ("grid_shape", "scenario", "pipeline", "stacked",
               "band_update")

#: config keys gated only when the baseline record carries them — the
#: schema-4 → 5 bridge.  ``segments`` (how many ragged stacks the
#: k-points split into under the scenario's padding budget) is part of
#: the measured configuration: a run whose segmentation changed executes
#: different batched transforms and is not comparable.  Schema-4
#: baselines predate the field and gate without it until refreshed.
OPTIONAL_CONFIG_KEYS = ("segments",)

#: serving metrics gated *when the baseline record carries them* (the
#: serve-transform scenario does; SCF scenarios don't and are unaffected).
#: ``transforms_per_s`` stays universal and required.  Each entry is
#: (record key, display name, direction); "lower" metrics (latency) gate
#: at twice the throughput tolerance — p99 on shared CI runners is far
#: noisier than sustained throughput, and a 20% latency gate would flake.
SERVE_METRICS = (
    ("requests_per_s", "requests/s", "higher"),
    ("latency_p99_ms", "p99 latency (ms)", "lower"),
)


def load_scenarios(path: str) -> dict:
    """Scenario dict of a BENCH_scf.json — schemas 2 through 5.

    Schema 4 adds a per-scenario ``metrics`` delta (obs-registry window);
    schema 5 adds ``segments``/``segment_padding_fractions`` (segmented
    ragged stacking) and ``grid_rank``.  Older baselines stay loadable
    through each transition — comparisons read specific keys, ``metrics``
    is attribution (never gated), and ``segments`` gates only when the
    baseline carries it (see OPTIONAL_CONFIG_KEYS).
    """
    with open(path) as f:
        record = json.load(f)
    if not isinstance(record, dict) or "scenarios" not in record:
        raise SystemExit(
            f"{path}: not a schema-2/3/4/5 BENCH_scf.json (missing "
            "'scenarios'); regenerate with benchmarks/run.py")
    return record["scenarios"]


def phase_attribution(rec: dict) -> list[str]:
    """Hints from a schema-4 record's embedded obs-metrics delta.

    When a scenario regressed, the counter deltas often say *where*: a
    burst of plan builds (cache thrash), extra transform executions, or
    per-k linalg calls (the stacked engine falling back).  Purely
    advisory — absent metrics (schema-3 records) yield no hints.
    """
    m = rec.get("metrics")
    if not isinstance(m, dict):
        return []
    # deltas can go negative when a scenario clears the plan cache inside
    # its window — only positive counts are meaningful hints
    hints: list[str] = []
    pc = m.get("plan_cache") or {}
    if pc.get("builds", 0) > 0:
        hints.append(f"{pc['builds']} plan build(s), "
                     f"{max(pc.get('build_seconds', 0.0), 0.0):.3f}s "
                     "building")
    if pc.get("evictions", 0) > 0:
        hints.append(f"{pc['evictions']} cache eviction(s)")
    fftb = m.get("fftb") or {}
    if fftb.get("executions", 0) > 0:
        hints.append(f"{fftb['executions']} transform execution(s)")
    dft = m.get("dft") or {}
    if dft.get("per_k_linalg_calls", 0) > 0:
        hints.append(f"{dft['per_k_linalg_calls']} per-k linalg call(s) "
                     "— stacked engine may have fallen back")
    return hints


def unknown_scenarios(current: dict, baseline: dict) -> list[str]:
    """Scenarios in the current run the baseline doesn't know about.

    Skipped (with a warning, never a ``KeyError`` or a failure): a freshly
    added scenario has no number to gate against until the baseline is
    refreshed.
    """
    return sorted(set(current) - set(baseline))


def compare_records(current: dict, baseline: dict,
                    tolerance: float = 0.20) -> list[str]:
    """Return the list of gate failures (empty = pass).

    Only scenarios the baseline knows about gate; see
    :func:`unknown_scenarios` for the skipped remainder.
    """
    failures: list[str] = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            failures.append(
                f"{name}: scenario present in baseline but missing from "
                "the current run")
            continue
        for key in CONFIG_KEYS:
            if cur.get(key) != base.get(key):
                failures.append(
                    f"{name}: {key} changed ({base.get(key)} -> "
                    f"{cur.get(key)}); refresh the baseline instead of "
                    "comparing different configurations")
        # optional config keys gate only when the baseline knows them —
        # a schema-4 baseline without ``segments`` compares as before
        for key in OPTIONAL_CONFIG_KEYS:
            if key in base and cur.get(key) != base.get(key):
                failures.append(
                    f"{name}: {key} changed ({base.get(key)} -> "
                    f"{cur.get(key)}); a different segmentation executes "
                    "different batched transforms — refresh the baseline "
                    "instead of comparing different configurations")
        if not cur.get("converged", False):
            failures.append(f"{name}: SCF did not converge")
        base_tps = base.get("transforms_per_s")
        cur_tps = cur.get("transforms_per_s")
        if base_tps is None or cur_tps is None:
            failures.append(
                f"{name}: record lacks transforms_per_s "
                f"(baseline={base_tps}, current={cur_tps}); regenerate "
                "with benchmarks/run.py")
            continue
        base_tps, cur_tps = float(base_tps), float(cur_tps)
        floor = base_tps * (1.0 - tolerance)
        if cur_tps < floor:
            failures.append(
                f"{name}: transforms/s regressed {base_tps:.1f} -> "
                f"{cur_tps:.1f} ({cur_tps / base_tps - 1.0:+.1%}, "
                f"tolerance -{tolerance:.0%})")
            hints = phase_attribution(cur)
            if hints:
                failures.append(
                    f"{name}: this run's metrics window — "
                    + "; ".join(hints))
        # serving metrics: gated only for scenarios whose baseline
        # records them (see SERVE_METRICS) — a baseline metric the
        # current run dropped is a failure, never a silent pass
        for key, label, direction in SERVE_METRICS:
            bv = base.get(key)
            if bv is None:
                continue
            cv = cur.get(key)
            if cv is None:
                failures.append(
                    f"{name}: record lacks {key} (baseline={bv}, "
                    "current=None); regenerate with benchmarks/run.py")
                continue
            bv, cv = float(bv), float(cv)
            if direction == "higher":
                if cv < bv * (1.0 - tolerance):
                    failures.append(
                        f"{name}: {label} regressed {bv:.1f} -> {cv:.1f} "
                        f"({cv / bv - 1.0:+.1%}, tolerance "
                        f"-{tolerance:.0%})")
            else:
                lat_tol = 2.0 * tolerance
                if cv > bv * (1.0 + lat_tol):
                    failures.append(
                        f"{name}: {label} regressed {bv:.1f} -> {cv:.1f} "
                        f"({cv / bv - 1.0:+.1%}, tolerance "
                        f"+{lat_tol:.0%})")
    return failures


def drifted_scenarios(current: dict, baseline: dict,
                      drift: float = 0.10) -> list[tuple]:
    """Gate-passing scenarios whose throughput moved >``drift`` either way.

    The baseline-refresh signal: only scenarios present in **both**
    records with matching configurations and usable ``transforms_per_s``
    qualify (everything else is the gate's business, not drift's).
    Returns ``[(name, base_tps, cur_tps, fraction), ...]`` with fraction
    signed (+0.25 = 25% faster than the baseline).
    """
    out: list[tuple] = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            continue
        if any(cur.get(k) != base.get(k) for k in CONFIG_KEYS):
            continue
        if any(k in base and cur.get(k) != base.get(k)
               for k in OPTIONAL_CONFIG_KEYS):
            continue
        base_tps = base.get("transforms_per_s")
        cur_tps = cur.get("transforms_per_s")
        if base_tps is None or cur_tps is None or float(base_tps) <= 0:
            continue
        frac = float(cur_tps) / float(base_tps) - 1.0
        if abs(frac) > drift:
            out.append((name, float(base_tps), float(cur_tps), frac))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_scf.json")
    ap.add_argument("baseline", help="committed benchmarks/baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional transforms/s drop "
                         "(default 0.20)")
    ap.add_argument("--check-drift", type=float, default=None,
                    metavar="FRAC",
                    help="after a passing gate, exit 2 when any "
                         "scenario's transforms/s moved more than FRAC "
                         "in either direction (the baseline-refresh "
                         "signal; e.g. 0.10)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with the current record "
                         "instead of gating")
    args = ap.parse_args(argv)

    current = load_scenarios(args.current)
    if args.update_baseline:
        with open(args.current) as f:
            record = json.load(f)
        with open(args.baseline, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"baseline {args.baseline} updated from {args.current} "
              f"(scenarios: {', '.join(sorted(current))})")
        return 0

    baseline = load_scenarios(args.baseline)

    def tps(rec):
        v = rec.get("transforms_per_s") if rec else None
        return f"{float(v):.1f}" if v is not None else "—"

    for name in sorted(set(current) | set(baseline)):
        cur, base = current.get(name), baseline.get(name)
        grid = (cur or base).get("grid_shape")
        print(f"{name:12s} grid={grid!s:8s} transforms/s "
              f"baseline={tps(base):>8s} current={tps(cur):>8s}")
    for name in unknown_scenarios(current, baseline):
        print(f"WARNING: {name}: scenario not in the baseline — skipped "
              "(run --update-baseline to start gating it)")
    failures = compare_records(current, baseline, args.tolerance)
    if failures:
        print("\nPERF GATE FAILED:")
        for msg in failures:
            print(f"  - {msg}")
        print("\nIf this is machine/runner drift rather than a code "
              "regression, refresh the baseline from a trusted run's "
              "BENCH_scf.json artifact:\n  python -m benchmarks.compare "
              "<artifact> benchmarks/baseline.json --update-baseline")
        return 1
    print(f"\nperf gate passed (tolerance -{args.tolerance:.0%}, "
          f"{len(baseline)} scenario(s))")
    if args.check_drift is not None:
        drifted = drifted_scenarios(current, baseline, args.check_drift)
        unknown = unknown_scenarios(current, baseline)
        if drifted or unknown:
            print("\nBASELINE STALE (gate still green):")
            for name, b, c, frac in drifted:
                print(f"  - {name}: {b:.1f} -> {c:.1f} ({frac:+.1%}, "
                      f"> {args.check_drift:.0%} drift)")
            for name in unknown:
                print(f"  - {name}: not in the baseline yet (ungated "
                      "until refreshed)")
            print("refresh with: python -m benchmarks.compare "
                  f"{args.current} {args.baseline} --update-baseline")
            return 2
        print(f"no drift beyond {args.check_drift:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
