"""Roofline analysis (§g deliverable): three terms per (arch × shape) from
the dry-run artifacts in experiments/dryrun.json.

    compute    = FLOPs_dev / peak_FLOPs          (197 TF bf16, v5e)
    memory     = bytes_dev / HBM_bw              (819 GB/s)
    collective = coll_bytes_dev / link_bw        (50 GB/s/link ICI)

`cost_analysis()` under SPMD reports *per-device* numbers (verified:
a 1024² matmul sharded 8-ways reports 2.68e8 = 2.1e9/8 FLOPs), so terms
divide by per-chip rates directly. FLOPs/bytes/collectives come from the
*accounting* records (unrolled scans, see dryrun.account_cell) when
available — rolled-scan records under-count loop bodies.

MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode/prefill use the
token count of the step (B·S for prefill, B for decode).

**Trace mode** (``--trace FILE``): instead of dry-run artifacts, analyse
a Chrome-trace JSON exported by the ``repro.obs`` tracer (per-stage plan
spans, ``--trace-out`` on the examples/bench).  Move-stage spans carry
the comm model's ``model_bytes_per_device`` tag, so measured all_to_all
wall time divides into modeled bytes → the *effective* per-device link
bandwidth each stage realized, next to the model's assumed peak — the
measured-vs-modeled comm comparison, per stage.

Run: PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
         [--trace trace.json]
"""
from __future__ import annotations

import argparse
import json
import os

PEAK = 197e12
HBM = 819e9
LINK = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun.json")


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs.base import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n * tokens
    return 2.0 * n * shape.batch          # decode: one token per sequence


def analyse(db: dict, mesh: str = "single"):
    mesh_tag = {"single": "16x16", "multi": "2x16x16"}[mesh]
    rows = []
    for key, v in sorted(db.items()):
        if "|acct" in key or "skipped" in v or "error" in v:
            continue
        if v.get("mesh") != mesh_tag or "flops" not in v:
            continue
        arch, shape = v["arch"], v["shape"]
        acct = db.get(f"{arch}|{shape}|{mesh}|acct")
        use_acct = bool(acct and "flops" in acct)
        if use_acct:
            # floor the L1/L2 extrapolation at the rolled-scan raw value
            # (a hard lower bound: scan bodies counted once) — guards
            # against negative slopes from per-depth XLA differences
            src = {k: max(acct[k], v[k])
                   for k in ("flops", "bytes_accessed", "collective_total")}
        else:
            src = v
        n_dev = v["n_devices"]
        t_comp = src["flops"] / PEAK
        t_mem = src["bytes_accessed"] / HBM
        t_coll = src["collective_total"] / LINK
        dom = max((t_comp, "compute"), (t_mem, "memory"),
                  (t_coll, "collective"))[1]
        if arch.startswith("fftb-paper"):
            mf = src["flops"] * n_dev          # the FFT *is* the model
        else:
            mf = model_flops(arch, shape)
        hlo_total = src["flops"] * n_dev
        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh_tag,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dom,
            "model_flops": mf,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            "peak_gib": v.get("peak_bytes_per_device", 0) / 2 ** 30,
            "accounted": use_acct,
        })
    return rows


def analyse_trace(trace: dict) -> dict:
    """Measured-vs-modeled transform telemetry from a tracer export.

    Aggregates the per-stage plan spans (``kind: fft`` line-DFT stages,
    ``kind: a2a`` move stages) of a Chrome-trace JSON.  For each a2a
    stage the modeled per-device bytes divide by measured wall seconds
    into an effective link bandwidth; stages far below ``LINK`` are
    latency- or layout-bound, not bandwidth-bound.
    """
    per_stage: dict[str, dict] = {}
    fft_s = a2a_s = a2a_bytes = 0.0
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        args = ev.get("args") or {}
        kind = args.get("kind")
        if kind not in ("fft", "a2a"):
            continue
        dur_s = float(ev.get("dur", 0.0)) * 1e-6
        s = per_stage.setdefault(ev["name"], {
            "kind": kind, "count": 0, "seconds": 0.0, "model_bytes": 0.0})
        s["count"] += 1
        s["seconds"] += dur_s
        if kind == "a2a":
            b = float(args.get("model_bytes_per_device", 0.0))
            s["model_bytes"] += b
            a2a_s += dur_s
            a2a_bytes += b
        else:
            fft_s += dur_s
    for s in per_stage.values():
        if s["kind"] == "a2a" and s["seconds"] > 0:
            s["effective_gbps"] = round(
                s["model_bytes"] / s["seconds"] / 1e9, 3)
    return {
        "fft_seconds": round(fft_s, 6),
        "a2a_seconds": round(a2a_s, 6),
        "a2a_model_bytes": a2a_bytes,
        "effective_link_gbps": round(
            a2a_bytes / a2a_s / 1e9 if a2a_s else 0.0, 3),
        "link_peak_gbps": LINK / 1e9,
        "per_stage": per_stage,
    }


def _bytes_accessed(jitted, *args) -> float | None:
    """'bytes accessed' from XLA's cost analysis, None when unavailable.

    CPU/interpret builds sometimes return no analysis (or a list per
    computation); treat every failure as "measured unavailable" so the
    report degrades to modeled-only instead of crashing.
    """
    try:
        cost = jitted.lower(*args).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else None
        if not cost:
            return None
        val = cost.get("bytes accessed")
        return float(val) if val is not None else None
    except Exception:
        return None


def analyse_sphere_kernels(n: int = 16, d: int = 8, nk: int = 2,
                           nbands: int = 4):
    """Measured-vs-modeled bytes for the fused sphere-pack kernels.

    Compares the composed hot-path legs (``unpack`` + plan / plan +
    ``pack``) against the fused pallas routes
    (``unpack_transform``/``transform_pack``) on a 1-device grid.  The
    byte model counts the packed operands and the first/last-stage slab
    once each; the composed route additionally writes the zero-padded
    (B, d³) bounding cube and reads it back for the line-DFT GEMM —
    16·B·d³ modeled bytes per direction that the fused kernels never
    touch (the two saved cube materializations).  Measured numbers come
    from XLA's ``cost_analysis`` when the backend provides one.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import (ProcGrid, kpoint_sphere,
                            make_stacked_planewave_pair)

    grid = ProcGrid.create([1])
    kpts = ((0.0, 0.0, 0.0), (0.5, 0.5, 0.5), (0.0, 0.5, 0.0))
    spheres = [kpoint_sphere(d, kp) for kp in kpts[:nk]]
    inv, fwd = make_stacked_planewave_pair(grid, n, spheres, nbands,
                                           backend="pallas")
    B = nk * nbands
    npm = inv.npacked_max
    rng = np.random.default_rng(0)
    packed = jnp.asarray(
        (rng.standard_normal((B, npm))
         + 1j * rng.standard_normal((B, npm))).astype(np.complex64))
    cube = inv(inv.unpack(packed))

    slab = 8.0 * B * d * d * n          # first/last-stage (B, d, d, n)
    pack_io = 8.0 * B * npm             # packed lanes, complex64
    cube_rw = 16.0 * B * d ** 3         # cube write + GEMM read-back
    rows = []
    for name, composed, fused, x in (
            ("unpack_dft",
             jax.jit(lambda p: inv(inv.unpack(p))),
             jax.jit(inv.unpack_transform), packed),
            ("dft_pack",
             jax.jit(lambda c: fwd.pack(fwd(c))),
             jax.jit(fwd.transform_pack), cube)):
        m_comp = _bytes_accessed(composed, x)
        m_fus = _bytes_accessed(fused, x)
        rows.append({
            "kernel": name,
            "modeled_composed_bytes": pack_io + slab + cube_rw,
            "modeled_fused_bytes": pack_io + slab,
            "modeled_saved_bytes": cube_rw,
            "measured_composed_bytes": m_comp,
            "measured_fused_bytes": m_fus,
            "measured_saved_bytes": (m_comp - m_fus)
            if m_comp is not None and m_fus is not None else None,
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    ap.add_argument("--csv", default="")
    ap.add_argument("--trace", default="", metavar="FILE",
                    help="analyse a Chrome-trace JSON from the repro.obs "
                         "tracer instead of the dry-run artifacts")
    ap.add_argument("--sphere-kernels", action="store_true",
                    help="report measured-vs-modeled bytes for the fused "
                         "sphere-pack pallas kernels against the composed "
                         "unpack/plan/pack route")
    args = ap.parse_args(argv)
    if args.sphere_kernels:
        rows = analyse_sphere_kernels()
        print(f"{'kernel':12s} {'route':9s} {'modeled_MiB':>12s} "
              f"{'measured_MiB':>13s}")
        for r in rows:
            for route in ("composed", "fused"):
                meas = r[f"measured_{route}_bytes"]
                print(f"{r['kernel']:12s} {route:9s} "
                      f"{r[f'modeled_{route}_bytes'] / 2 ** 20:12.3f} "
                      + (f"{meas / 2 ** 20:13.3f}" if meas is not None
                         else f"{'n/a':>13s}"))
            saved = r["measured_saved_bytes"]
            print(f"{'':12s} {'saved':9s} "
                  f"{r['modeled_saved_bytes'] / 2 ** 20:12.3f} "
                  + (f"{saved / 2 ** 20:13.3f}" if saved is not None
                     else f"{'n/a':>13s}")
                  + "   (bounding-cube write + read the fusion skips)")
        return rows
    if args.trace:
        with open(args.trace) as f:
            rep = analyse_trace(json.load(f))
        print(f"{'stage':28s} {'kind':5s} {'count':>6s} {'total_s':>10s} "
              f"{'model_MiB':>10s} {'eff_GB/s':>9s}")
        for name, s in sorted(rep["per_stage"].items()):
            eff = s.get("effective_gbps")
            print(f"{name:28s} {s['kind']:5s} {s['count']:6d} "
                  f"{s['seconds']:10.4f} "
                  f"{s['model_bytes'] / 2 ** 20:10.2f} "
                  + (f"{eff:9.2f}" if eff is not None else f"{'—':>9s}"))
        print(f"fft total {rep['fft_seconds']:.4f}s, "
              f"a2a total {rep['a2a_seconds']:.4f}s, effective link "
              f"{rep['effective_link_gbps']:.2f} GB/s "
              f"(model peak {rep['link_peak_gbps']:.0f})")
        return rep
    with open(RESULTS) as f:
        db = json.load(f)
    rows = analyse(db, args.mesh)
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'peakGiB':>8s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:10.3e} "
              f"{r['t_memory_s']:10.3e} {r['t_collective_s']:10.3e} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
              f"{r['peak_gib']:8.2f}" + ("" if r["accounted"] else "  (raw)"))
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")
    return rows


if __name__ == "__main__":
    main()
