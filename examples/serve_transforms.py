"""Multi-tenant transform serving — replay a mixed trace, print metrics.

Four tenants share one :class:`~repro.serve.TransformService`: two cutoffs
× two k-shifts (three batch-compatibility classes — the two k-shifts of
the large cutoff coalesce into shared stacked dispatches, the small cutoff
rides its own), every request checked bitwise against per-request eager
dispatch.  Ends by printing the service's metrics summary: per-tenant
p50/p99 latency, requests/s, realized padding fraction, and the shared
PlanCache's hit rate over the trace.

Run:  PYTHONPATH=src python examples/serve_transforms.py \\
          [--requests 32] [--n 16] [--d 8] [--grid 1] [--budget 0.5] \\
          [--trace-out trace.json]
      (XLA_FLAGS=--xla_force_host_platform_device_count=4 with --grid 4
       to serve distributed transforms; d and n must divide the grid;
       --trace-out writes a Perfetto-loadable span trace — dispatch spans
       nest transforms nest per-stage FFT/all_to_all, with per-request
       queue-wait events on the side)
"""
import argparse
import json

import numpy as np

from repro.core import ProcGrid, global_plan_cache, kpoint_sphere
from repro.obs.trace import get_tracer
from repro.serve import TransformService


def build_trace(n, d, d_small, requests, rng):
    """(tenant, coeffs, sphere, v_eff) tuples: two cutoffs × two k-shifts."""
    shapes = [
        ("alpha", kpoint_sphere(d), 2),                    # Γ, large cutoff
        ("beta", kpoint_sphere(d, (0.5, 0.5, 0.5)), 2),    # k-shifted
        ("gamma", kpoint_sphere(d_small), 1),              # small cutoff, Γ
        ("delta", kpoint_sphere(d_small, (0.5, 0.0, 0.0)), 1),
    ]
    veff = rng.standard_normal((n,) * 3).astype(np.float32)
    trace = []
    for i in range(requests):
        tenant, sphere, nbands = shapes[i % len(shapes)]
        c = (rng.standard_normal((nbands, sphere.npacked))
             + 1j * rng.standard_normal((nbands, sphere.npacked))
             ).astype(np.complex64)
        trace.append((tenant, c, sphere, veff if i % 2 == 0 else None))
    return trace


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--n", type=int, default=16, help="FFT cube width")
    ap.add_argument("--d", type=int, default=8,
                    help="large cut-off sphere diameter")
    ap.add_argument("--d-small", type=int, default=None,
                    help="small cut-off diameter (default d/2)")
    ap.add_argument("--grid", type=int, default=1,
                    help="fft-axis process count")
    ap.add_argument("--budget", type=float, default=0.5,
                    help="padding-fraction budget for coalescing")
    ap.add_argument("--max-rows", type=int, default=8)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(per-stage plan spans, device-synced at span "
                         "exit — slows the run, timings stay honest)")
    args = ap.parse_args(argv)
    d_small = args.d_small if args.d_small is not None else args.d // 2
    if args.trace_out:
        get_tracer().enable(sync=True, per_stage=True)

    grid = ProcGrid.create([args.grid], ["dft_f"])
    global_plan_cache().clear()
    svc = TransformService(grid, args.n, padding_budget=args.budget,
                           max_rows=args.max_rows, warm_async=False)
    rng = np.random.default_rng(0)
    trace = build_trace(args.n, args.d, d_small, args.requests, rng)

    handles = [svc.submit(t, c, s, v_eff=v) for t, c, s, v in trace]
    svc.run_until_idle()

    mismatches = sum(
        not np.array_equal(h.result(10), svc.eager_apply(c, s, v))
        for h, (_, c, s, v) in zip(handles, trace))
    m = svc.metrics.summary()
    print(json.dumps(m, indent=2))
    print(f"coalesced {m['coalesced_dispatches']}/{m['dispatches']} "
          f"dispatches, padding ≤ {m['padding_fraction_max']:.3f} "
          f"(budget {args.budget})")
    assert mismatches == 0, f"{mismatches} results differ from eager"
    print("all results bitwise-equal to eager dispatch ✓")
    if args.trace_out:
        tr = get_tracer()
        tr.disable()
        tr.export_chrome(args.trace_out)
        print(f"trace: {len(tr.events())} spans -> {args.trace_out} "
              "(load in https://ui.perfetto.dev)")
    return m


if __name__ == "__main__":
    main()
