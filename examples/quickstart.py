"""Quickstart — the paper's Fig. 6 walkthrough on the builder API.

Creates a processing grid, declares the transform with one arrow-spec
string (input dims → output dims; renamed dims are transformed, annotated
dims are distributed), builds the plan, and runs it::

    g    = ProcGrid.create([nproc])
    fx   = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g)
    y    = fx(x)
    x2   = fx.inverse()(y)            # derived mirror — no second planning

One-shot calls can skip plan handling entirely — ``fftb.apply`` memoizes
plans in a process-global LRU cache::

    y = fftb.apply("x{0} y z -> X Y Z{0}", x, domains=dom, grid=g)

Run:  PYTHONPATH=src python examples/quickstart.py
      (set XLA_FLAGS=--xla_force_host_platform_device_count=8 to see the
       distributed schedule with real all-to-alls)
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import Domain, ProcGrid, fftb, global_plan_cache


def main():
    # 1. processing grid (1D here; 2D/3D work the same way)
    nproc = len(jax.devices())
    g = ProcGrid.create([nproc])
    print(f"grid: {g}")

    # 2. declare the transform: 64³ cube, x-distributed in, z-distributed
    #    out — the planner derives the schedule from the spec alone
    n = 64
    dom = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
    fx = fftb("x{0} y z -> X Y Z{0}", domains=dom, grid=g)
    print(fx.describe())
    print("comm per device:", fx.comm_stats())

    # 3. execute and validate
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((n, n, n))
         + 1j * rng.standard_normal((n, n, n))).astype(np.complex64)
    y = np.asarray(fx(jnp.asarray(x)))
    ref = np.fft.fftn(x)
    err = np.abs(y - ref).max() / np.abs(ref).max()
    print(f"max rel err vs numpy.fft: {err:.2e}")
    assert err < 1e-5

    # 4. the inverse is derived from the same stage list (no re-planning)
    x2 = np.asarray(fx.inverse()(jnp.asarray(y)))
    rt = np.abs(x2 - x).max()
    print(f"inverse()(fx(x)) roundtrip err: {rt:.2e}")
    assert rt < 1e-4

    # 5. one-shot cached form: same plan object on every repeat call
    y2 = fftb.apply("x{0} y z -> X Y Z{0}", jnp.asarray(x), domains=dom,
                    grid=g)
    np.testing.assert_allclose(np.asarray(y2), y, rtol=0, atol=0)
    fftb.apply("x{0} y z -> X Y Z{0}", jnp.asarray(x), domains=dom, grid=g)
    print("plan cache:", global_plan_cache().stats)


if __name__ == "__main__":
    main()
