"""Quickstart — the paper's Fig. 6 walkthrough in FFTB-JAX.

Creates a processing grid, declares distributed input/output tensors with
dims-strings, builds a 3D FFT plan, and runs it. Mirrors the C++ snippet:

    grid g = grid(procs, MPI_COMM_WORLD);
    tensor ti = tensor(dom_in,  "x{0} y z", g);
    tensor to = tensor(dom_out, "X Y Z{0}", g);
    fftb  fx = fftb(sizes, to, "X Y Z", ti, "x y z", g);

Run:  PYTHONPATH=src python examples/quickstart.py
      (set XLA_FLAGS=--xla_force_host_platform_device_count=8 to see the
       distributed schedule with real all-to-alls)
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import Domain, DistTensor, ProcGrid, fftb


def main():
    # 1. processing grid (1D here; 2D/3D work the same way)
    nproc = len(jax.devices())
    g = ProcGrid.create([nproc])
    print(f"grid: {g}")

    # 2. input/output tensors: 64³ cube, x-distributed in, z-distributed out
    n = 64
    dom = Domain((0, 0, 0), (n - 1, n - 1, n - 1))
    ti = DistTensor.create(dom, "x{0} y z", g)
    to = DistTensor.create(dom, "X Y Z{0}", g)

    # 3. create the transform — the planner picks the schedule
    fx = fftb((n, n, n), to, "X Y Z", ti, "x y z", g)
    print(fx.describe())
    print("comm per device:", fx.comm_stats())

    # 4. execute and validate
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((n, n, n))
         + 1j * rng.standard_normal((n, n, n))).astype(np.complex64)
    y = np.asarray(fx(jnp.asarray(x)))
    ref = np.fft.fftn(x)
    err = np.abs(y - ref).max() / np.abs(ref).max()
    print(f"max rel err vs numpy.fft: {err:.2e}")
    assert err < 1e-5


if __name__ == "__main__":
    main()
