"""Serve a small model with batched requests through the continuous-
batching engine (prefill + decode slots, slot reuse on completion).

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 2
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    reqs = main(sys.argv[1:])
    assert all(r.done for r in reqs)
    print("all requests served ✓")
