"""Plane-wave DFT — thin CLI over the ``repro.dft`` SCF subsystem.

The paper's target application, end to end: a self-consistent Kohn-Sham
calculation where every hot operation is an FFTB plan — per-k-point sphere
transforms (a batch of *different* spheres, bands batched within each, one
plan per sphere served from the process-global PlanCache) interleaved with
full-cube density/potential transforms for the G-space Hartree solve.

Run:  PYTHONPATH=src python examples/planewave_dft.py \\
          [--n 16] [--bands 4] [--kpts "0,0,0;0.5,0.5,0.5"] [--grid 2x2] \\
          [--trace-out trace.json]
      (XLA_FLAGS=--xla_force_host_platform_device_count=4 to distribute;
       --grid auto picks 1D fft vs 2D batch×fft from the problem shape;
       --trace-out writes a Perfetto-loadable span trace — SCF iterations
       nest transforms nest per-stage FFT/all_to_all spans)
"""
import argparse

from repro.core import ExecPolicy, ProcGrid, global_plan_cache
from repro.dft import SCFConfig, run_scf
from repro.obs.trace import get_tracer
from repro.sharding.grids import (DFT_AXES_1D, DFT_AXES_2D, DFT_AXES_3D,
                                  choose_dft_grid)


def parse_kpts(spec: str):
    """'0,0,0;0.5,0.5,0.5' → ((0,0,0), (0.5,0.5,0.5))."""
    return tuple(tuple(float(x) for x in part.split(","))
                 for part in spec.split(";") if part.strip())


def parse_grid(spec: str, cfg: SCFConfig):
    """'auto' | '4' | '2x2' | '2x2x2' → ProcGrid (1D fft-only, 2D
    batch×fft, 3D batch×fft×fft pencil — the PlaneWaveBasis convention:
    first axis batch, trailing axes decompose the fft)."""
    if spec == "auto":
        return choose_dft_grid(nbands=cfg.nbands, nk=len(cfg.kpts),
                               diameter=cfg.diameter or cfg.n // 2)
    shape = [int(p) for p in spec.lower().split("x")]
    try:
        names = {1: DFT_AXES_1D, 2: DFT_AXES_2D, 3: DFT_AXES_3D}[len(shape)]
    except KeyError:
        raise SystemExit(f"--grid {spec!r}: at most 3 axes "
                         "(batch x fft x fft)")
    return ProcGrid.create(shape, list(names))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=16, help="FFT cube width")
    ap.add_argument("--diameter", type=int, default=None,
                    help="cut-off sphere diameter (default n/2)")
    ap.add_argument("--bands", type=int, default=4)
    ap.add_argument("--kpts", default="0,0,0;0.5,0.5,0.5",
                    help="semicolon-separated reduced k-points")
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--inner-steps", type=int, default=4)
    ap.add_argument("--mix-alpha", type=float, default=0.7)
    ap.add_argument("--depth", type=float, default=4.0)
    ap.add_argument("--no-xc", action="store_true",
                    help="drop the LDA exchange term")
    ap.add_argument("--policy", default="eager",
                    choices=["eager", "lazy", "lazy_bf16"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grid", default="auto",
                    help="processing grid: 'auto', '4' (1D fft), "
                         "'2x2' (batch×fft 2D), or '2x2x2' "
                         "(batch×fft×fft pencil)")
    ap.add_argument("--segment-padding", type=float, default=None,
                    metavar="FRAC",
                    help="per-segment padding budget for the stacked "
                         "route: split the ragged k-stack into segments "
                         "whose realized padding stays under FRAC "
                         "(default: one segment padded to the global "
                         "max sphere)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="serial per-k loop instead of the double-buffered "
                         "k-point pipeline")
    ap.add_argument("--stack-k", default="auto",
                    choices=["auto", "on", "off"],
                    help="ragged k-stacked H applies + the batched "
                         "band-update engine: 'auto' engages when the "
                         "grid shards the nk·nbands batch evenly "
                         "(basis.stacks_k), 'on'/'off' force the route")
    ap.add_argument("--jit-step", action="store_true",
                    help="fuse mixing + band update + density into one "
                         "jit-compiled step per outer iteration "
                         "(requires the stacked route; combine with "
                         "--stack-k on to force it on small grids)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(per-stage plan spans, device-synced at span "
                         "exit — slows the run, timings stay honest)")
    args = ap.parse_args(argv)
    if args.trace_out:
        get_tracer().enable(sync=True, per_stage=True)

    cfg = SCFConfig(
        n=args.n, diameter=args.diameter, nbands=args.bands,
        kpts=parse_kpts(args.kpts), max_iter=args.iters, e_tol=args.tol,
        inner_steps=args.inner_steps, mix_alpha=args.mix_alpha,
        depth=args.depth, xc=not args.no_xc, seed=args.seed,
        pipeline=not args.no_pipeline,
        stack_k={"auto": None, "on": True, "off": False}[args.stack_k],
        jit_step=args.jit_step,
        segment_padding=args.segment_padding,
        policy=ExecPolicy.from_mode(args.policy))
    grid = parse_grid(args.grid, cfg)

    import jax
    print(f"devices={jax.device_count()}  grid={grid}  n={cfg.n}  "
          f"bands={cfg.nbands}  k-points={len(cfg.kpts)}")

    def progress(it, e, r):
        if it % 5 == 0:
            print(f"iter {it:3d}  E = {e:+.7f}  |Δρ| = {r:.3e}")

    res = run_scf(cfg, grid=grid, callback=progress)

    print(f"\n{'converged' if res.converged else 'NOT converged'} in "
          f"{res.iterations} iterations:  E = {res.energy:+.7f}")
    for ik, eps in enumerate(res.eigenvalues):
        print(f"  k[{ik}] eigenvalues: "
              + "  ".join(f"{e:+.4f}" for e in eps))
    route = (f"stacked band updates ({res.segments} segment(s), padding "
             f"{res.padding_fraction:.1%})" if res.stacked
             else "pipelined per-k H applies" if cfg.pipeline
             else "serial per-k H applies")
    if res.jitted:
        route += ", fused jit step"
    print(f"{res.transforms} per-band 3D transforms in {res.seconds:.2f}s "
          f"({res.transforms_per_s:.1f} transforms/s, batched over "
          f"{cfg.nbands} bands per plan call, {route})")
    c = res.cache_stats
    total = c["hits"] + c["misses"]
    print(f"plan cache: {c['misses']} builds, {c['hits']} hits "
          f"({c['hits'] / max(total, 1):.1%} hit rate) — "
          f"{global_plan_cache()!r}")
    if args.trace_out:
        tr = get_tracer()
        tr.disable()
        tr.export_chrome(args.trace_out)
        summ = tr.summary()
        top = sorted(summ.items(), key=lambda kv: -kv[1]["total_ms"])[:8]
        print(f"\ntrace: {len(tr.events())} spans -> {args.trace_out} "
              "(load in https://ui.perfetto.dev)")
        for name, s in top:
            print(f"  {name:28s} x{s['count']:<5d} {s['total_ms']:9.2f} ms")


if __name__ == "__main__":
    main()
