"""Plane-wave DFT mini-app — the paper's target application, end to end.

Solves the lowest bands of a Kohn-Sham-like eigenproblem
    H ψ = (−½∇² + V_loc) ψ
in a plane-wave basis truncated to the cut-off sphere (paper Fig. 2/7),
using the *all-band* preconditioned steepest-descent/CG iteration the paper
describes (§2.2): every step applies batched FFTB transforms
sphere→real-space (apply V) →sphere, exactly the red-line workload of
Fig. 9. Bands are kept orthonormal with a Gram-Schmidt (QR) step — the
matrix-matrix form that batching enables.

The forward transform is *derived* from the inverse plan (one schedule
search per pair), and the execution policy is declarative: pass
``--policy lazy_bf16`` to pin an executor, or ``--policy tune`` to let
``plan.tune()`` race the candidates and pin the fastest.

Run:  PYTHONPATH=src python examples/planewave_dft.py [--n 32] [--bands 8]
      (XLA_FLAGS=--xla_force_host_platform_device_count=8 to distribute)
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (ExecPolicy, ProcGrid, SphereDomain,
                        make_planewave_pair)


def build_hamiltonian(n, sph, inv, fwd):
    """Kinetic |g|²/2 on sphere coefficients + Gaussian wells in real
    space — a minimal but faithful plane-wave Hamiltonian."""
    idx = np.argwhere(sph.mask())
    g2 = ((idx - np.asarray(sph.center)) ** 2).sum(1).astype(np.float32)
    kin = jnp.asarray(0.5 * g2 * (2 * np.pi / n) ** 2)
    xs = np.stack(np.meshgrid(*[np.arange(n)] * 3, indexing="ij"), -1)
    centers = [(n * 0.3,) * 3, (n * 0.7,) * 3]
    v = np.zeros((n, n, n), np.float32)
    for c in centers:
        v -= 4.0 * np.exp(-((xs - np.asarray(c)) ** 2).sum(-1)
                          / (2 * (n / 16) ** 2))
    vloc = jnp.asarray(v)

    def h_apply(c):                       # c: (nb, npacked)
        psi = inv(inv.unpack(c))          # sphere → real space (batched)
        hv = fwd(psi * vloc)              # V ψ, back to sphere cube
        return kin * c + inv.pack(hv)

    return h_apply, kin


def orthonormalize(c):
    q, _ = jnp.linalg.qr(c.T)             # bands are columns
    return q.T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--bands", type=int, default=8)
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--policy", default="eager",
                    choices=["eager", "lazy", "lazy_bf16", "tune"])
    args = ap.parse_args(argv)

    nproc = len(jax.devices())
    g = ProcGrid.create([nproc])
    sph = SphereDomain.from_diameter(args.n // 2)
    policy = None if args.policy == "tune" \
        else ExecPolicy.from_mode(args.policy)
    inv, fwd = make_planewave_pair(g, args.n, sph, args.bands,
                                   policy=policy)
    print(f"grid={g}  sphere d={sph.extents[0]} "
          f"({sph.npacked} coeffs = {sph.npacked/args.n**3:.1%} of cube)")
    print(inv.describe())
    if args.policy == "tune":
        d = sph.extents[0]
        probe = jnp.ones((args.bands, d, d, d), jnp.complex64)
        fwd.policy = inv.tune(probe)      # pair shares the winning policy
        print("tuned:", inv.policy)

    h_apply, kin = build_hamiltonian(args.n, sph, inv, fwd)
    precond = 1.0 / (1.0 + jnp.asarray(kin))      # kinetic preconditioner

    @jax.jit
    def step(c):
        hc = h_apply(c)
        lam = jnp.sum(jnp.conj(c) * hc, axis=1).real      # Rayleigh
        grad = hc - lam[:, None] * c
        c = c - args.lr * (precond[None, :] * grad)
        return orthonormalize(c), lam, jnp.linalg.norm(grad, axis=1)

    rng = np.random.default_rng(0)
    c = (rng.standard_normal((args.bands, sph.npacked))
         + 1j * rng.standard_normal((args.bands, sph.npacked))
         ).astype(np.complex64)
    c = np.asarray(orthonormalize(jnp.asarray(c)))
    c = jnp.asarray(c)

    t0 = time.perf_counter()
    hist = []
    for it in range(args.iters):
        c, lam, res = step(c)
        e = float(lam.sum())
        hist.append(e)
        if it % 5 == 0 or it == args.iters - 1:
            print(f"iter {it:3d}  E = {e:+.6f}  max|res| = "
                  f"{float(res.max()):.3e}")
    dt = time.perf_counter() - t0
    ffts = args.iters * 2 * args.bands            # fwd+inv per band per it
    print(f"\n{args.iters} all-band iterations in {dt:.2f}s "
          f"({ffts} batched 3D transforms, "
          f"{ffts/dt:.1f} transforms/s on {nproc} device(s))")
    assert hist[-1] < hist[0], "energy must decrease"
    drops = sum(1 for a, b in zip(hist, hist[1:]) if b > a + 1e-4)
    print(f"energy decreased {hist[0]:+.4f} → {hist[-1]:+.4f} "
          f"({drops} non-monotone steps)")


if __name__ == "__main__":
    main()
