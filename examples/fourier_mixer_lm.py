"""Beyond-paper demo: FFTB as a *layer* inside an LM (FNet-style mixing).

Swaps a tiny transformer's attention for `repro.core.fourier_mixer`
(Re(FFT_seq(FFT_hidden(x)))) — demonstrating the paper's infrastructure as
a composable JAX module in the model stack, not just a standalone library.
Trains both variants on the same synthetic data and reports losses.

    PYTHONPATH=src python examples/fourier_mixer_lm.py --steps 60
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fourier_mixer
from repro.data.pipeline import DataConfig, Pipeline
from repro.models.layers import dense_init, mlp_apply, mlp_init, rms_norm


def init_params(key, vocab, d, layers, d_ff):
    ks = jax.random.split(key, 2 + layers)
    return {
        "embed": dense_init(ks[0], (vocab, d), scale=0.02),
        "layers": [{"ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
                    "mlp": mlp_init(k, d, d_ff, "gelu", jnp.float32)}
                   for k in ks[1:-1]],
        "ln_f": jnp.zeros((d,)),
    }


def forward(params, tokens):
    x = params["embed"][tokens]
    for lp in params["layers"]:
        h = rms_norm(x, lp["ln1"], 1e-6)
        x = x + fourier_mixer(h)                 # FFTB spectral mixing
        h = rms_norm(x, lp["ln2"], 1e-6)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
    h = rms_norm(x, params["ln_f"], 1e-6)
    return h @ params["embed"].T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args(argv)
    vocab, d, L, dff, B, S = 256, 64, 2, 128, 4, 32
    params = init_params(jax.random.PRNGKey(0), vocab, d, L, dff)
    pipe = Pipeline(DataConfig(vocab=vocab, seq=S, global_batch=B))

    def loss_fn(p, batch):
        logits = forward(p, batch["tokens"])
        lse = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["labels"][..., None],
                                   -1)[..., 0]
        return (lse - gold).mean()

    @jax.jit
    def step(p, batch):
        l, g = jax.value_and_grad(loss_fn)(p, batch)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), l

    losses = []
    fixed = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    for s in range(args.steps):
        params, l = step(params, fixed)      # memorization curve
        losses.append(float(l))
        if s % 20 == 0:
            print(f"step {s:3d} loss {l:.4f}")
    print(f"fourier-mixer LM: {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0]
    print("spectral mixing layer trains ✓ (FFTB as a model component)")


if __name__ == "__main__":
    main()
