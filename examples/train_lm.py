"""Train an LM end-to-end with the full production stack: deterministic
data pipeline, AdamW, remat, checkpointing, auto-resume, straggler monitor.

Presets:
  cpu-ci  reduced model, a few hundred steps in minutes on CPU (default)
  100m    ~100M-param model (same family) — the assignment's train driver;
          run it on real accelerators, it is far too slow for 1 CPU core

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch mamba2-370m --steps 50
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--steps") for a in args):
        args += ["--steps", "200"]
    if "--fixed-batch" not in args:
        args += ["--fixed-batch"]     # memorization curve: CI-stable signal
    trainer = main(args)
    losses = [h["loss"] for h in trainer.history]
    if len(losses) >= 20:
        first = sum(losses[:10]) / 10
        last = sum(losses[-10:]) / 10
        print(f"mean(first 10)={first:.4f}  mean(last 10)={last:.4f}")
        assert last < first, "training must reduce loss"
        print("loss decreased ✓")
